"""Paged flash-decode Pallas kernel: one query token vs a KV cache stored
as fixed-size pages scattered through a physical page pool, gathered via a
per-sequence block-index map — the kernel-level realization of the serving
pager's page grain (`serving/kv_pager.py` hands out exactly this layout
via `KVPager.block_table`).

The block tables and lengths ride the scalar-prefetch channel
(`pltpu.PrefetchScalarGridSpec`): they are resident in SMEM before the
kernel body runs, so the K/V BlockSpec index maps can chase
`bt[b, page_idx]` to DMA each NON-CONTIGUOUS physical page while the
previous page's flash update is still computing — the same
fetch-one-page-ahead overlap the prefetch subsystem models at the tier
level, here done by Mosaic's double-buffered pipeline at the VMEM level.

Block-quantized pools (`repro.kernels.quant`): with int8 page payloads the
per-page float32 (scale, zero) arrays ride the SAME scalar-prefetch
channel next to the block table, and the kernel applies the dequant
epilogue `q * scale + zero` right after each page's gather — the fp
values never exist in HBM, only in the VMEM tile the flash update is
about to consume, so the pool-link bytes are the int8 payload plus the
per-page scalars and nothing else.

Grid (B, H, n_logical_pages); the page dimension is sequential
("arbitrary") so the online-softmax accumulators live in VMEM scratch
across iterations, exactly like the dense `decode_attention.py` kernel.
Out-of-length positions are masked by an iota test against `lengths`;
block-table entries past a sequence's last valid page must still name a
real physical page (the public wrapper in ops.py clamps them to 0) so the
gather stays in bounds — the mask keeps them out of the math.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_update(s, v, acc, m_sc, l_sc):
    """One page's online-softmax update of the (1, D) accumulator.
    s: (page,) masked logits; v: (page, D) float32 values."""
    m_prev = m_sc[0]
    m_new = jnp.maximum(m_prev, s.max())
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_sc[0] = l_sc[0] * alpha + p.sum()
    m_sc[0] = m_new
    acc[...] = acc[...] * alpha + (p[:, None] * v).sum(axis=0)[None, :]


def _kernel(*refs, page: int, scale: float, n_pages: int, rep: int,
            sz_mode: str):
    if sz_mode == "page":
        (bt_ref, len_ref, ksz_ref, vsz_ref, q_ref, k_ref, v_ref, o_ref,
         acc, m_sc, l_sc) = refs
    elif sz_mode == "token":
        (bt_ref, len_ref, q_ref, k_ref, v_ref, ksz_ref, vsz_ref, o_ref,
         acc, m_sc, l_sc) = refs
    else:
        (bt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
         acc, m_sc, l_sc) = refs
    b = pl.program_id(0)
    pi = pl.program_id(2)

    @pl.when(pi == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)

    q = q_ref[0, 0, :].astype(jnp.float32)            # (D,)
    k = k_ref[0, :, 0, :].astype(jnp.float32)         # (page, D)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    if sz_mode == "page":
        # fused dequant epilogue: the page's (scale, zero) scalars sit in
        # SMEM next to the block table entry that fetched it
        pid = bt_ref[b, pi]
        kvh = pl.program_id(1) // rep
        k = k * ksz_ref[pid, kvh, 0] + ksz_ref[pid, kvh, 1]
        v = v * vsz_ref[pid, kvh, 0] + vsz_ref[pid, kvh, 1]
    elif sz_mode == "token":
        # per-token sub-scales travel as VMEM tensor blocks next to the
        # page payload (one (page, 2) tile per grid step, same
        # bt-chasing index map), dequantized row-wise
        k = k * ksz_ref[0, :, 0, 0][:, None] + ksz_ref[0, :, 0, 1][:, None]
        v = v * vsz_ref[0, :, 0, 0][:, None] + vsz_ref[0, :, 0, 1][:, None]

    s = (k @ q) * scale                               # (page,)
    pos = pi * page + jax.lax.iota(jnp.int32, page)   # logical positions
    valid = pos < len_ref[b]
    s = jnp.where(valid, s, NEG_INF)
    _flash_update(s, v, acc, m_sc, l_sc)

    @pl.when(pi == n_pages - 1)
    def _done():
        o_ref[0, 0, :] = (
            acc[0] / jnp.maximum(l_sc[0], 1e-30)
        ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def paged_flash_decode(q, k_pages, v_pages, block_tables, lengths, *,
                       k_sz=None, v_sz=None, scale=None,
                       interpret: bool = False):
    """q (B,H,D) vs paged cache k/v (P_phys, page, KV, D) through
    block_tables (B, n_logical_pages) int32 physical-page ids; `lengths`
    (B,) valid token counts. Logical page `i` of sequence `b` holds
    tokens [i*page, (i+1)*page) and lives at physical page
    `block_tables[b, i]`. Entries past the valid length must be in
    [0, P_phys) — use ops.paged_decode_mha, which clamps.

    With `k_sz`/`v_sz` float32 (scale, zero) arrays, the pool payload is
    int8 and the kernel dequantizes each gathered page in the epilogue
    (`repro.kernels.quant` layout). The sz grain dispatches on rank:
    per-page (P_phys, KV, 2) rides the scalar-prefetch channel;
    per-token (P_phys, page, KV, 2) — the speculative-decoding
    sub-scale layout — travels as regular tensor operands whose
    BlockSpec chases the same block-table entry as the payload."""
    from jax.experimental.pallas import tpu as pltpu

    B, H, D = q.shape
    _, page, KV, _ = k_pages.shape
    n_pages = block_tables.shape[1]
    rep = H // KV
    if k_sz is None:
        sz_mode = "none"
    elif jnp.ndim(k_sz) == k_pages.ndim:
        sz_mode = "token"
    else:
        sz_mode = "page"
    scale = scale if scale is not None else D ** -0.5
    lengths = jnp.broadcast_to(jnp.asarray(lengths, jnp.int32), (B,))
    block_tables = jnp.asarray(block_tables, jnp.int32)

    page_spec = pl.BlockSpec(
        (1, page, 1, D),
        (lambda b, h, pi, bt, ln, *sz, rep=rep: (bt[b, pi], 0, h // rep, 0)),
    )
    in_specs = [
        pl.BlockSpec((1, 1, D),
                     lambda b, h, pi, bt, ln, *sz: (b, h, 0)),
        page_spec,
        page_spec,
    ]
    operands = (q, k_pages, v_pages)
    if sz_mode == "token":
        sz_spec = pl.BlockSpec(
            (1, page, 1, 2),
            (lambda b, h, pi, bt, ln, rep=rep: (bt[b, pi], 0, h // rep, 0)),
        )
        in_specs += [sz_spec, sz_spec]
        operands += (jnp.asarray(k_sz, jnp.float32),
                     jnp.asarray(v_sz, jnp.float32))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        # block tables + lengths (+ per-page k/v (scale, zero) when int8)
        num_scalar_prefetch=4 if sz_mode == "page" else 2,
        grid=(B, H, n_pages),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, D),
                               lambda b, h, pi, bt, ln, *sz: (b, h, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, D), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
        ],
    )
    scalars = (block_tables, lengths)
    if sz_mode == "page":
        scalars += (jnp.asarray(k_sz, jnp.float32),
                    jnp.asarray(v_sz, jnp.float32))
    return pl.pallas_call(
        functools.partial(_kernel, page=page, scale=scale, n_pages=n_pages,
                          rep=rep, sz_mode=sz_mode),
        out_shape=jax.ShapeDtypeStruct((B, H, D), q.dtype),
        grid_spec=grid_spec,
        interpret=interpret,
        compiler_params=pltpu.CompilerParams(
            # MEGACORE partitioning: batch and head grid dimensions are
            # "parallel" so Mosaic splits them across TensorCores; only
            # the page dimension is sequential (online-softmax carry)
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ) if not interpret else None,
    )(*scalars, *operands)
