"""Pure-jnp oracle for single-token decode attention against a KV cache.

Written in grouped-GQA einsum form (no jnp.repeat): broadcasting the KV
heads to Q heads makes XLA SPMD replicate a sequence-sharded cache
(measured: 40 GB of all-gather per decoded token on the 16x16 mesh); the
grouped contraction partitions cleanly along the sharded sequence dim with
only an (B,H,1)-sized psum for the softmax statistics.
"""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp


def gather_pages(pages: jnp.ndarray, block_tables) -> jnp.ndarray:
    """Assemble a dense (B, S, KV, D) cache from a physical page pool
    (P_phys, page, KV, D) through (B, n_logical) block tables — the
    oracle's view of the paged layout (and the parity test's bridge
    between `KVPager.block_table` and the dense reference)."""
    block_tables = jnp.asarray(block_tables, jnp.int32)
    g = pages[block_tables]                 # (B, n_logical, page, KV, D)
    B, n, page, KV, D = g.shape
    return g.reshape(B, n * page, KV, D)


def gather_pages_q8(pages: jnp.ndarray, sz: jnp.ndarray, block_tables,
                    dtype=jnp.float32) -> jnp.ndarray:
    """`gather_pages` for a block-quantized pool: int8 payload
    (P_phys, page, KV, D) plus (scale, zero) ``sz`` float32
    (`repro.kernels.quant` layout), dequantized to a dense (B, S, KV, D)
    cache. The sz grain is dispatched on rank: per-page
    (P_phys, KV, 2) or per-token (P_phys, page, KV, 2) — the
    speculative-decoding sub-scale layout."""
    from repro.kernels import quant

    block_tables = jnp.asarray(block_tables, jnp.int32)
    g = pages[block_tables]                 # (B, n_logical, page, KV, D)
    s = sz[block_tables]
    if sz.ndim == pages.ndim:               # per-token sub-scales
        d = quant.dequantize_tokens(g, s, dtype=dtype)
    else:                                   # per-page (B, n, KV, 2)
        d = quant.dequantize_pages(g, s, dtype=dtype)
    B, n, page, KV, D = d.shape
    return d.reshape(B, n * page, KV, D)


def paged_decode_mha(q, k_pages, v_pages, block_tables, lengths, *,
                     k_sz=None, v_sz=None, scale=None) -> jnp.ndarray:
    """Paged oracle: gather to dense (dequantizing int8 pools through the
    per-page (scale, zero) arrays when given), then the dense oracle."""
    if k_sz is not None:
        k = gather_pages_q8(k_pages, k_sz, block_tables, dtype=q.dtype)
        v = gather_pages_q8(v_pages, v_sz, block_tables, dtype=q.dtype)
    else:
        k = gather_pages(k_pages, block_tables)
        v = gather_pages(v_pages, block_tables)
    return decode_mha(q, k, v, lengths, scale=scale)


def decode_mha(
    q: jnp.ndarray,       # (B, H, D) one new token per sequence
    k: jnp.ndarray,       # (B, S, KV, D) cache
    v: jnp.ndarray,       # (B, S, KV, D)
    length,               # int or (B,) valid prefix length(s)
    *,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    B, H, D = q.shape
    _, S, KV, _ = k.shape
    R = H // KV
    scale = scale if scale is not None else D ** -0.5
    length = jnp.asarray(length)
    if length.ndim == 0:
        length = jnp.full((B,), length)

    qg = q.reshape(B, KV, R, D)
    logits = jnp.einsum(
        "bgrd,bsgd->bgrs", qg, k,
        preferred_element_type=jnp.float32,
    ) * scale                                            # (B,KV,R,S) f32
    mask = jnp.arange(S)[None, :] < length[:, None]      # (B,S)
    logits = jnp.where(mask[:, None, None, :], logits, -jnp.inf)
    m = logits.max(axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    l = p.sum(axis=-1, keepdims=True)
    out = jnp.einsum(
        "bgrs,bsgd->bgrd", p.astype(v.dtype), v,
        preferred_element_type=jnp.float32,
    ) / l[..., 0:1]
    return out.reshape(B, H, D).astype(q.dtype)
