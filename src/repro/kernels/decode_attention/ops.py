"""Public decode-attention op (the serving hot loop)."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import select_impl
from repro.kernels.decode_attention import ref


@functools.partial(jax.jit, static_argnames=("scale", "impl"))
def decode_mha(
    q,
    k,
    v,
    length,
    *,
    scale: Optional[float] = None,
    impl: Optional[str] = None,
):
    """q (B,H,D) vs cache k/v (B,S,KV,D) with valid `length`."""
    kind, interpret = select_impl(impl)
    if kind == "reference":
        return ref.decode_mha(q, k, v, length, scale=scale)
    from repro.kernels.decode_attention import decode_attention as da

    return da.flash_decode(q, k, v, length, scale=scale,
                           interpret=interpret)


def clamp_dead_entries(block_tables, n_pages, page, frontier):
    """Clamp block-table entries at/past the per-sequence `frontier`
    (valid token count for decode; the causal frontier c0+C for chunked
    prefill — `flash_attention.ops` shares this helper) to physical page
    0 so the gather stays in bounds on every backend; the kernels' masks
    keep them out of the math."""
    live = (
        jnp.arange(n_pages, dtype=jnp.int32)[None, :] * page
        < frontier[:, None]
    )
    return jnp.where(live, jnp.asarray(block_tables, jnp.int32), 0)


@functools.partial(jax.jit, static_argnames=("scale", "impl"))
def paged_decode_mha(
    q,
    k_pages,
    v_pages,
    block_tables,
    lengths,
    *,
    k_sz=None,
    v_sz=None,
    scale: Optional[float] = None,
    impl: Optional[str] = None,
):
    """q (B,H,D) vs a PAGED cache: k/v (P_phys, page, KV, D) physical page
    pool + (B, n_logical) block tables (`KVPager.block_table` layout) with
    per-sequence valid `lengths`. Block-table entries past the valid
    length are clamped to physical page 0 so the gather stays in bounds
    on every backend; the length mask keeps them out of the math.

    `k_sz`/`v_sz` (P_phys, KV, 2) float32 switch the pool to int8 block
    quantization (`repro.kernels.quant`): the payload is int8 and the
    kernel (or oracle) dequantizes each gathered page with its per-page
    (scale, zero) pair."""
    n_pages = block_tables.shape[1]
    page = k_pages.shape[1]
    lengths = jnp.broadcast_to(
        jnp.asarray(lengths, jnp.int32), (q.shape[0],)
    )
    block_tables = clamp_dead_entries(block_tables, n_pages, page, lengths)
    kind, interpret = select_impl(impl)
    if kind == "reference":
        return ref.paged_decode_mha(q, k_pages, v_pages, block_tables,
                                    lengths, k_sz=k_sz, v_sz=v_sz,
                                    scale=scale)
    from repro.kernels.decode_attention import paged as pg

    return pg.paged_flash_decode(
        q, k_pages, v_pages, block_tables, lengths, k_sz=k_sz, v_sz=v_sz,
        scale=scale, interpret=interpret,
    )
