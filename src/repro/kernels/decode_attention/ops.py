"""Public decode-attention op (the serving hot loop)."""

from __future__ import annotations

import functools
from typing import Optional

import jax

from repro import kernels
from repro.kernels.decode_attention import ref


@functools.partial(jax.jit, static_argnames=("scale", "impl"))
def decode_mha(
    q,
    k,
    v,
    length,
    *,
    scale: Optional[float] = None,
    impl: Optional[str] = None,
):
    """q (B,H,D) vs cache k/v (B,S,KV,D) with valid `length`."""
    impl = impl or kernels.backend()
    if impl == "reference":
        return ref.decode_mha(q, k, v, length, scale=scale)
    from repro.kernels.decode_attention import decode_attention as da

    return da.flash_decode(
        q, k, v, length, scale=scale, interpret=(impl == "interpret")
    )
