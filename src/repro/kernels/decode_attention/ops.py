"""Public decode-attention op (the serving hot loop)."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro import kernels
from repro.kernels.decode_attention import ref


@functools.partial(jax.jit, static_argnames=("scale", "impl"))
def decode_mha(
    q,
    k,
    v,
    length,
    *,
    scale: Optional[float] = None,
    impl: Optional[str] = None,
):
    """q (B,H,D) vs cache k/v (B,S,KV,D) with valid `length`."""
    impl = impl or kernels.backend()
    if impl == "reference":
        return ref.decode_mha(q, k, v, length, scale=scale)
    from repro.kernels.decode_attention import decode_attention as da

    return da.flash_decode(
        q, k, v, length, scale=scale, interpret=(impl == "interpret")
    )


@functools.partial(jax.jit, static_argnames=("scale", "impl"))
def paged_decode_mha(
    q,
    k_pages,
    v_pages,
    block_tables,
    lengths,
    *,
    scale: Optional[float] = None,
    impl: Optional[str] = None,
):
    """q (B,H,D) vs a PAGED cache: k/v (P_phys, page, KV, D) physical page
    pool + (B, n_logical) block tables (`KVPager.block_table` layout) with
    per-sequence valid `lengths`. Block-table entries past the valid
    length are clamped to physical page 0 so the gather stays in bounds
    on every backend; the length mask keeps them out of the math."""
    n_pages = block_tables.shape[1]
    page = k_pages.shape[1]
    lengths = jnp.broadcast_to(
        jnp.asarray(lengths, jnp.int32), (q.shape[0],)
    )
    live = (
        jnp.arange(n_pages, dtype=jnp.int32)[None, :] * page
        < lengths[:, None]
    )
    block_tables = jnp.where(live, jnp.asarray(block_tables, jnp.int32), 0)
    impl = impl or kernels.backend()
    if impl == "reference":
        return ref.paged_decode_mha(q, k_pages, v_pages, block_tables,
                                    lengths, scale=scale)
    from repro.kernels.decode_attention import paged as pg

    return pg.paged_flash_decode(
        q, k_pages, v_pages, block_tables, lengths, scale=scale,
        interpret=(impl == "interpret"),
    )
