"""Reproduction of "A Quantitative Approach for Adopting Disaggregated
Memory in HPC Systems".

Importing the package eagerly loads :mod:`repro.common.parallel`, whose
import installs the jax version-compat shims (``jax.sharding.AxisType`` and
the ``axis_types=`` kwarg of ``jax.make_mesh``) so every entry point —
including bare subprocess snippets that only import one leaf module — sees
a uniform jax surface.
"""

from repro.common import parallel as _parallel  # noqa: F401  (compat shims)
