"""Layer-pattern derivation and super-block scan assembly.

Heterogeneous layer stacks (Jamba's 1:7 attn:mamba with period-2 MoE) are
handled by scanning over *super-blocks*: the layer pattern repeats with
period = lcm(moe_period, attn_period); params for each position-in-period are
stacked over the num_layers/period blocks, and `lax.scan` runs over blocks.
HLO size is therefore independent of depth while the layer pattern is exact.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.common.config import ModelConfig
from repro.common.parallel import ParallelCtx
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import mlp, mlp_init, rmsnorm, rmsnorm_init
from repro.models.module import Initializer, stack_inits


@dataclasses.dataclass(frozen=True)
class LayerDesc:
    kind: str            # "attn" | "ssm"
    moe: bool
    cross: bool = False  # enc-dec decoder layer with cross-attention
    causal: bool = True


def super_period(cfg: ModelConfig) -> int:
    p = 1
    if cfg.num_experts:
        p = math.lcm(p, cfg.moe_layer_period)
    if cfg.family == "hybrid" and cfg.attn_layer_period:
        p = math.lcm(p, cfg.attn_layer_period)
    return p


def pattern(cfg: ModelConfig, cross: bool = False, causal: bool = True):
    per = super_period(cfg)
    assert cfg.num_layers % per == 0, (cfg.num_layers, per)
    return [
        LayerDesc(
            kind="attn" if cfg.is_attn_layer(j) else "ssm",
            moe=cfg.is_moe_layer(j),
            cross=cross,
            causal=causal,
        )
        for j in range(per)
    ]


# ------------------------------------------------------------------ init
def layer_init(key, cfg: ModelConfig, desc: LayerDesc):
    init = Initializer(key, jnp.dtype(cfg.param_dtype))
    rmsnorm_init(init.child("pre_norm"), cfg.d_model)
    if desc.kind == "attn":
        attn.attn_init(init.child("attn"), cfg)
    else:
        ssm_mod.ssm_init(init.child("ssm"), cfg)
    if desc.cross:
        rmsnorm_init(init.child("cross_norm"), cfg.d_model)
        attn.attn_init(init.child("cross"), cfg, cross=True)
    rmsnorm_init(init.child("ffn_norm"), cfg.d_model)
    if desc.moe:
        moe_mod.moe_init(init.child("moe"), cfg)
    elif cfg.d_ff:
        mlp_init(init.child("mlp"), cfg)
    return init.collect()


def stack_init(key, cfg: ModelConfig, cross: bool = False,
               causal: bool = True, n_layers: Optional[int] = None):
    """Init all layers, stacked by position-in-period. Returns (params, axes)."""
    descs = pattern(cfg, cross, causal)
    n_layers = n_layers if n_layers is not None else cfg.num_layers
    nb = n_layers // len(descs)
    params, axes = {}, {}
    keys = jax.random.split(key, len(descs))
    for j, desc in enumerate(descs):
        pj, aj = stack_inits(
            lambda k, d=desc: layer_init(k, cfg, d), keys[j], nb
        )
        params[f"pos{j}"] = pj
        axes[f"pos{j}"] = aj
    return params, axes


# ------------------------------------------------------------------ apply
def _apply_layer_train(p, x, cfg: ModelConfig, desc: LayerDesc,
                       ctx: ParallelCtx, enc_out=None):
    """One layer, full-sequence. Returns (x, aux)."""
    aux = jnp.zeros((), jnp.float32)
    h = rmsnorm(p["pre_norm"], x, cfg.norm_eps)
    if desc.kind == "attn":
        h = attn.self_attention(p["attn"], h, cfg, causal=desc.causal,
                                ctx=ctx)
    else:
        h = ssm_mod.ssm_block(p["ssm"], h, cfg)
    x = x + h
    if desc.cross:
        h = rmsnorm(p["cross_norm"], x, cfg.norm_eps)
        enc_kv = attn.encode_cross_kv(p["cross"], enc_out, cfg)
        x = x + attn.cross_attention(p["cross"], h, enc_kv, cfg)
    h = rmsnorm(p["ffn_norm"], x, cfg.norm_eps)
    if desc.moe:
        h, a = moe_mod.moe_ep(p["moe"], h, cfg, ctx)
        aux = aux + a
    elif cfg.d_ff:
        h = mlp(p["mlp"], h, cfg)
    else:
        h = jnp.zeros_like(x)
    return x + h, aux


def stack_apply(params, x, cfg: ModelConfig, ctx: ParallelCtx,
                cross: bool = False, causal: bool = True, enc_out=None):
    """Full-sequence stack (training / prefill without cache)."""
    descs = pattern(cfg, cross, causal)

    def body(carry, blk):
        x, aux = carry
        for j, desc in enumerate(descs):
            x, a = _apply_layer_train(blk[f"pos{j}"], x, cfg, desc, ctx,
                                      enc_out)
            aux = aux + a
        return (x, aux), None

    if ctx.remat == "block":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable
        )
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), params)
    return x, aux


# --------------------------------------------------------------- caches
# pool payload dtypes for the paged KV cache: "fp" stores cfg.dtype
# exactly (the bit-identical safety net), "bf16" is a 2-byte cast-only
# pool, "int8" adds per-page float32 (scale, zero) arrays ("k_sz"/"v_sz"
# leaves, `repro.kernels.quant` layout) with quantize-on-insert and
# dequantize-in-kernel
POOL_DTYPES = ("fp", "bf16", "int8")


def pool_kv_dtype(cfg: ModelConfig, pool_dtype: str):
    """Resolve a pool-dtype name to the K/V payload jnp dtype."""
    if pool_dtype not in POOL_DTYPES:
        raise ValueError(f"unknown pool_dtype {pool_dtype!r}; "
                         f"expected one of {POOL_DTYPES}")
    if pool_dtype == "fp":
        return jnp.dtype(cfg.dtype)
    return jnp.dtype({"bf16": jnp.bfloat16, "int8": jnp.int8}[pool_dtype])


def init_caches(cfg: ModelConfig, batch: int, max_seq: int,
                cross: bool = False, enc_len: int = 0, kv_shape=None,
                kv_dtype=None, kv_sz_shape=None):
    """Decode caches, mirroring the stacked-params structure. `kv_shape`/
    `kv_dtype` override the self-attention K/V leaf shape and dtype (the
    paged pool layout — see `init_paged_caches`); the K/V buffers are the
    engine's largest arrays, so they are allocated directly in their
    final shape. `kv_sz_shape` adds the per-page float32 (scale, zero)
    leaves of an int8 block-quantized pool."""
    descs = pattern(cfg, cross)
    nb = cfg.num_layers // len(descs)
    dtype = jnp.dtype(cfg.dtype)
    caches = {}
    for j, desc in enumerate(descs):
        c = {}
        if desc.kind == "attn":
            shape = kv_shape or (nb, batch, max_seq, cfg.num_kv_heads,
                                 cfg.head_dim)
            c["k"] = jnp.zeros(shape, kv_dtype or dtype)
            c["v"] = jnp.zeros(shape, kv_dtype or dtype)
            if kv_sz_shape is not None:
                c["k_sz"] = jnp.zeros(kv_sz_shape, jnp.float32)
                c["v_sz"] = jnp.zeros(kv_sz_shape, jnp.float32)
        else:
            H, Pd, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
            gn = ssm_mod.NGROUPS * N
            W = cfg.conv_width
            c["state"] = jnp.zeros((nb, batch, H, Pd, N), jnp.float32)
            c["tail_x"] = jnp.zeros((nb, batch, W - 1, cfg.d_inner), dtype)
            c["tail_B"] = jnp.zeros((nb, batch, W - 1, gn), dtype)
            c["tail_C"] = jnp.zeros((nb, batch, W - 1, gn), dtype)
        if desc.cross:
            shape = (nb, batch, enc_len, cfg.num_kv_heads, cfg.head_dim)
            c["cross_k"] = jnp.zeros(shape, dtype)
            c["cross_v"] = jnp.zeros(shape, dtype)
        caches[f"pos{j}"] = c
    return caches


def init_paged_caches(cfg: ModelConfig, n_slots: int, max_seq: int,
                      page_tokens: int, cross: bool = False,
                      enc_len: int = 0, pool_dtype: str = "fp",
                      sz_granularity: str = "page"):
    """Decode caches with self-attention K/V laid out as a PHYSICAL page
    pool: (nb, n_slots * n_pages, page_tokens, KV, hd) instead of the
    per-slot contiguous (nb, n_slots, max_seq, KV, hd). Each valid
    (slot, logical page) owns one physical page handed out by the serving
    pager's free list; the (n_slots, n_pages) block table maps between
    them at every cache read/write. Non-attention state (SSM state, conv
    tails, cross-KV) is resident per slot and keeps the dense layout.

    `pool_dtype` picks the pool payload (see `POOL_DTYPES`): "fp" keeps
    cfg.dtype bit-identically; "bf16" stores a 2-byte cast; "int8" stores
    int8 payload plus float32 (scale, zero) arrays as "k_sz"/"v_sz"
    leaves. `sz_granularity` picks the quantization grain of those
    leaves: "page" (default) stores one pair per (physical page, KV head)
    — (nb, p_phys, KV, 2); "token" stores one pair per (page row, KV
    head) — (nb, p_phys, page_tokens, KV, 2) — the speculative-decoding
    hot-page layout whose token writes are pure disjoint scatters
    (`kernels.quant.quantize_tokens`). The kernels dispatch on the static
    rank of the sz leaf, so both layouts flow through the same cells."""
    if sz_granularity not in ("page", "token"):
        raise ValueError(f"unknown sz_granularity {sz_granularity!r}; "
                         "expected 'page' or 'token'")
    descs = pattern(cfg, cross)
    nb = cfg.num_layers // len(descs)
    n_pages = -(-max_seq // page_tokens)       # ceil
    p_phys = n_slots * n_pages
    if pool_dtype != "int8":
        sz_shape = None
    elif sz_granularity == "token":
        sz_shape = (nb, p_phys, page_tokens, cfg.num_kv_heads, 2)
    else:
        sz_shape = (nb, p_phys, cfg.num_kv_heads, 2)
    return init_caches(
        cfg, n_slots, max_seq, cross=cross, enc_len=enc_len,
        kv_shape=(nb, p_phys, page_tokens, cfg.num_kv_heads, cfg.head_dim),
        kv_dtype=pool_kv_dtype(cfg, pool_dtype),
        kv_sz_shape=sz_shape,
    )


# the physical-page-pool leaves of a paged cache tree; everything else
# (SSM state, conv tails, cross-KV) is slot-resident and never pooled
PAGED_LEAF_NAMES = ("k", "v", "k_sz", "v_sz")


def init_pool_twin(caches):
    """Pool-resident twin of the PAGED leaves of `caches`: a same-shape
    zeros tree holding only the physical page pool arrays (k/v payload
    plus the int8 (scale, zero) leaves). The serving substrate
    (`repro.serving.substrate`) places it — `pinned_host` NamedSharding
    in physical mode, default memory when emulated — and mirrors
    pool-tiered pages into it via the jitted transfer streams. Returns
    {} for cache trees with no paged leaves (SSM-only stacks)."""
    twin = {}
    for pos, c in caches.items():
        sub = {name: jnp.zeros(c[name].shape, c[name].dtype)
               for name in PAGED_LEAF_NAMES if name in c}
        if sub:
            twin[pos] = sub
    return twin


def _apply_layer_decode(p, c, x, t, cfg: ModelConfig, desc: LayerDesc,
                        ctx: ParallelCtx, block_table=None,
                        page_tokens: int = 0, attn_override=None):
    """One layer, one token (or, via `attn_override`, one prompt chunk).
    Returns (x, new_cache). With a block table the attention K/V lives in
    the physical page pool layout (fp or block-quantized — the paged
    paths read and return the whole attention cache dict, so the int8
    "k_sz"/"v_sz" leaves ride along invisibly); `attn_override(p_attn,
    h, c) -> (h, cache_updates)` swaps the attention contraction while
    the rest of the layer body stays shared (the chunked-prefill path —
    one body, so a layer change cannot silently diverge the chunked and
    serialized streams)."""
    nc = dict(c)
    h = rmsnorm(p["pre_norm"], x, cfg.norm_eps)
    if desc.kind == "attn":
        if attn_override is not None:
            h, updates = attn_override(p["attn"], h, c)
            nc.update(updates)
        elif block_table is not None:
            h, updates = attn.paged_decode_self_attention(
                p["attn"], h, cfg, c, t, block_table, page_tokens,
            )
            nc.update(updates)
        else:
            h, (nc["k"], nc["v"]) = attn.decode_self_attention(
                p["attn"], h, cfg, c["k"], c["v"], t
            )
    else:
        h, (nc["state"], (nc["tail_x"], nc["tail_B"], nc["tail_C"])) = (
            ssm_mod.ssm_decode_step(
                p["ssm"], h, cfg, c["state"],
                (c["tail_x"], c["tail_B"], c["tail_C"]),
            )
        )
    x = x + h
    if desc.cross:
        h = rmsnorm(p["cross_norm"], x, cfg.norm_eps)
        x = x + attn.decode_cross_attention(
            p["cross"], h, (c["cross_k"], c["cross_v"]), cfg
        )
    h = rmsnorm(p["ffn_norm"], x, cfg.norm_eps)
    if desc.moe:
        h, _ = moe_mod.moe_ep(p["moe"], h, cfg, ctx)
    elif cfg.d_ff:
        h = mlp(p["mlp"], h, cfg)
    else:
        h = jnp.zeros_like(x)
    return x + h, nc


def stack_decode(params, caches, x, t, cfg: ModelConfig, ctx: ParallelCtx,
                 cross: bool = False, block_table=None,
                 page_tokens: int = 0):
    """One decode step through the whole stack. x: (B, 1, d). With
    `block_table`, attention caches are the paged pool layout."""
    descs = pattern(cfg, cross)

    def body(x, inp):
        blk, cache = inp
        new_cache = {}
        for j, desc in enumerate(descs):
            x, new_cache[f"pos{j}"] = _apply_layer_decode(
                blk[f"pos{j}"], cache[f"pos{j}"], x, t, cfg, desc, ctx,
                block_table=block_table, page_tokens=page_tokens,
            )
        return x, new_cache

    x, new_caches = jax.lax.scan(body, x, (params, caches))
    return x, new_caches


def stack_prefill_chunk(params, caches, x, c0, cfg: ModelConfig,
                        ctx: ParallelCtx, block_row, page_tokens: int):
    """One page-aligned prompt chunk through the whole stack against the
    PAGED caches: each attention layer writes the chunk's KV through the
    block table and flash-attends to everything prefilled so far. Only
    attention-only decoder stacks chunk (an SSM/conv prefix is a
    sequential reduction over the prompt; see
    `runtime.serve.chunked_prefill_supported`). x: (1, C, d)."""
    descs = pattern(cfg, cross=False)
    if any(d.kind != "attn" or d.cross for d in descs):
        raise ValueError("chunked prefill needs an attention-only stack")

    def chunk_attn(p_attn, h, c):
        return attn.paged_prefill_chunk_attention(
            p_attn, h, cfg, c, c0, block_row, page_tokens
        )

    def body(x, inp):
        blk, cache = inp
        new_cache = {}
        for j, desc in enumerate(descs):
            x, new_cache[f"pos{j}"] = _apply_layer_decode(
                blk[f"pos{j}"], cache[f"pos{j}"], x, None, cfg, desc, ctx,
                attn_override=chunk_attn,
            )
        return x, new_cache

    x, new_caches = jax.lax.scan(body, x, (params, caches))
    return x, new_caches


def stack_prefill(params, x, t0, cfg: ModelConfig, ctx: ParallelCtx,
                  max_seq: int, cross: bool = False, enc_out=None):
    """Prefill: full-sequence forward that also materializes decode caches."""
    descs = pattern(cfg, cross)
    B, S, _ = x.shape
    dtype = jnp.dtype(cfg.dtype)

    def body(carry, blk):
        x, aux = carry
        cache = {}
        for j, desc in enumerate(descs):
            p = blk[f"pos{j}"]
            c = {}
            h = rmsnorm(p["pre_norm"], x, cfg.norm_eps)
            if desc.kind == "attn":
                h, (k, v) = attn.self_attention(
                    p["attn"], h, cfg, causal=desc.causal, return_kv=True,
                    ctx=ctx,
                )
                pad = max_seq - S
                c["k"] = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
                c["v"] = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
            else:
                h, (state, tails) = ssm_mod.ssm_block(
                    p["ssm"], h, cfg, return_state=True
                )
                c["state"] = state
                c["tail_x"], c["tail_B"], c["tail_C"] = tails
            x = x + h
            if desc.cross:
                hh = rmsnorm(p["cross_norm"], x, cfg.norm_eps)
                ck, cv = attn.encode_cross_kv(p["cross"], enc_out, cfg)
                c["cross_k"], c["cross_v"] = ck, cv
                x = x + attn.cross_attention(p["cross"], hh, (ck, cv), cfg)
            h = rmsnorm(p["ffn_norm"], x, cfg.norm_eps)
            if desc.moe:
                h, a = moe_mod.moe_ep(p["moe"], h, cfg, ctx)
                aux = aux + a
            elif cfg.d_ff:
                h = mlp(p["mlp"], h, cfg)
            else:
                h = jnp.zeros_like(x)
            x = x + h
            cache[f"pos{j}"] = c
        return (x, aux), cache

    (x, aux), caches = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), params
    )
    return x, caches
