"""Modality frontend STUBS (per the brief): the transformer backbone is built
in full; the SigLIP vision tower / speech feature extractor are replaced by
precomputed patch/frame embeddings supplied as model inputs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common.config import ModelConfig


def frontend_embed_shape(cfg: ModelConfig, batch: int, seq_len: int):
    """Shape of the precomputed frontend embeddings for a workload cell.

    vision_stub: fixed num_prefix_tokens patch embeddings per example.
    audio_stub : seq_len encoder frames per example (the encoder consumes
                 the frames; the decoder length is the text side).
    """
    if cfg.frontend == "vision_stub":
        return (batch, cfg.num_prefix_tokens, cfg.d_model)
    if cfg.frontend == "audio_stub":
        return (batch, seq_len, cfg.d_model)
    return None


def synthetic_frontend_embeds(cfg: ModelConfig, batch: int, seq_len: int,
                              key=None):
    shape = frontend_embed_shape(cfg, batch, seq_len)
    if shape is None:
        return None
    key = key if key is not None else jax.random.PRNGKey(17)
    return jax.random.normal(key, shape, jnp.dtype(cfg.dtype)) * 0.02
