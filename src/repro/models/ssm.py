"""Mamba2 (SSD) block: in_proj -> causal depthwise conv -> selective SSM ->
gated RMSNorm -> out_proj.

The scan itself is kernels/ssd_scan (chunked Pallas on TPU, exact jnp scan
elsewhere). Decode keeps two small states per layer: the SSM state
(B, H, P, N) and the conv tail (B, W-1, channels).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common.config import ModelConfig
from repro.kernels.ssd_scan import ops as ssd_ops
from repro.models.module import Initializer

NGROUPS = 1  # B/C groups (mamba2 default)


def ssm_init(init: Initializer, cfg: ModelConfig):
    d, di, n = cfg.d_model, cfg.d_inner, cfg.ssm_state
    h, w = cfg.ssm_heads, cfg.conv_width
    gn = NGROUPS * n
    init.param("w_in_x", (d, di), ("embed", "ssm_inner"))
    init.param("w_in_z", (d, di), ("embed", "ssm_inner"))
    init.param("w_in_B", (d, gn), ("embed", None))
    init.param("w_in_C", (d, gn), ("embed", None))
    init.param("w_in_dt", (d, h), ("embed", "ssm_heads"))
    init.param("conv_x", (w, di), (None, "ssm_inner"), scale=0.5)
    init.param("conv_B", (w, gn), (None, None), scale=0.5)
    init.param("conv_C", (w, gn), (None, None), scale=0.5)
    init.param("A_log", (h,), ("ssm_heads",), init="zeros")
    init.param("D", (h,), ("ssm_heads",), init="ones")
    init.param("dt_bias", (h,), ("ssm_heads",), init="zeros")
    init.param("norm_scale", (di,), ("ssm_inner",), init="ones")
    init.param("w_out", (di, d), ("ssm_inner", "embed"))


def _causal_conv(x, w, tail=None):
    """Depthwise causal conv. x (B,S,C), w (W,C), tail (B,W-1,C) or None.

    Returns (y (B,S,C), new_tail (B,W-1,C)).
    """
    W = w.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)          # (B, S+W-1, C)
    y = sum(
        xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(W)
    )
    new_tail = xp[:, -(W - 1) :, :] if W > 1 else tail
    return y, new_tail


def _project(params, x, cfg: ModelConfig):
    dt_ = x.dtype
    xs = jnp.einsum("bsd,di->bsi", x, params["w_in_x"].astype(dt_))
    z = jnp.einsum("bsd,di->bsi", x, params["w_in_z"].astype(dt_))
    Bm = jnp.einsum("bsd,dn->bsn", x, params["w_in_B"].astype(dt_))
    Cm = jnp.einsum("bsd,dn->bsn", x, params["w_in_C"].astype(dt_))
    dt = jnp.einsum("bsd,dh->bsh", x, params["w_in_dt"].astype(dt_))
    return xs, z, Bm, Cm, dt


def _gated_norm(params, y, z, eps: float):
    g = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    g32 = g.astype(jnp.float32)
    var = jnp.mean(g32 * g32, axis=-1, keepdims=True)
    return (
        g32 * jax.lax.rsqrt(var + eps) * params["norm_scale"].astype(jnp.float32)
    ).astype(y.dtype)


def ssm_block(params, x, cfg: ModelConfig, return_state: bool = False,
              init_state=None, conv_tail=None):
    """Full-sequence SSD block. x: (B, S, d)."""
    B, S, d = x.shape
    H, Pd, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    xs, z, Bm, Cm, dt = _project(params, x, cfg)

    xs, tail_x = _causal_conv(xs, params["conv_x"].astype(x.dtype),
                              conv_tail[0] if conv_tail else None)
    Bm, tail_B = _causal_conv(Bm, params["conv_B"].astype(x.dtype),
                              conv_tail[1] if conv_tail else None)
    Cm, tail_C = _causal_conv(Cm, params["conv_C"].astype(x.dtype),
                              conv_tail[2] if conv_tail else None)
    xs, Bm, Cm = jax.nn.silu(xs), jax.nn.silu(Bm), jax.nn.silu(Cm)

    dtp = jax.nn.softplus(
        dt.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32)
    )
    A = -jnp.exp(params["A_log"].astype(jnp.float32))

    y, state = ssd_ops.ssd(
        xs.reshape(B, S, H, Pd),
        dtp,
        A,
        Bm.reshape(B, S, NGROUPS, N),
        Cm.reshape(B, S, NGROUPS, N),
        params["D"],
        init_state,
    )
    y = _gated_norm(params, y.reshape(B, S, cfg.d_inner), z, cfg.norm_eps)
    out = jnp.einsum("bsi,id->bsd", y, params["w_out"].astype(x.dtype))
    if return_state:
        return out, (state, (tail_x, tail_B, tail_C))
    return out


def ssm_decode_step(params, x, cfg: ModelConfig, state, conv_tail):
    """One-token decode. x (B,1,d); state (B,H,P,N); conv_tail 3-tuple."""
    B, _, d = x.shape
    H, Pd, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    xs, z, Bm, Cm, dt = _project(params, x, cfg)

    def step_conv(xt, w, tail):
        # tail (B, W-1, C), xt (B,1,C)
        xp = jnp.concatenate([tail, xt], axis=1)        # (B, W, C)
        y = jnp.einsum("bwc,wc->bc", xp, w)[:, None, :]
        return y, xp[:, 1:, :]

    xs, tail_x = step_conv(xs, params["conv_x"].astype(x.dtype), conv_tail[0])
    Bm, tail_B = step_conv(Bm, params["conv_B"].astype(x.dtype), conv_tail[1])
    Cm, tail_C = step_conv(Cm, params["conv_C"].astype(x.dtype), conv_tail[2])
    xs, Bm, Cm = jax.nn.silu(xs), jax.nn.silu(Bm), jax.nn.silu(Cm)

    dtp = jax.nn.softplus(
        dt.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32)
    )[:, 0]                                             # (B,H)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    y, new_state = ssd_ops.ssd_decode(
        xs[:, 0].reshape(B, H, Pd),
        dtp,
        A,
        Bm[:, 0].reshape(B, NGROUPS, N),
        Cm[:, 0].reshape(B, NGROUPS, N),
        params["D"],
        state,
    )
    y = _gated_norm(params, y.reshape(B, 1, cfg.d_inner), z, cfg.norm_eps)
    out = jnp.einsum("bsi,id->bsd", y, params["w_out"].astype(x.dtype))
    return out, (new_state, (tail_x, tail_B, tail_C))


def make_ssm_state(cfg: ModelConfig, batch: int, n_layers: int, dtype=None):
    dtype = jnp.float32  # SSM state kept in fp32 for recurrence stability
    H, Pd, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    gn = NGROUPS * cfg.ssm_state
    state = jnp.zeros((n_layers, batch, H, Pd, N), dtype)
    cdt = jnp.dtype(cfg.dtype)
    W = cfg.conv_width
    tails = (
        jnp.zeros((n_layers, batch, W - 1, cfg.d_inner), cdt),
        jnp.zeros((n_layers, batch, W - 1, gn), cdt),
        jnp.zeros((n_layers, batch, W - 1, gn), cdt),
    )
    return state, tails
