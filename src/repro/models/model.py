"""Top-level model API used by the runtime, launcher, tests and benchmarks.

Families:
  dense/moe/ssm/hybrid/vlm -> decoder-only LM (vlm prepends patch embeddings)
  audio (enc-dec)          -> encoder over frame embeddings + causal decoder
                              with per-layer cross-attention

Public surface:
  init_model(cfg, key)                        -> (params, axes)
  forward(params, batch, cfg, ctx)            -> (logits, aux)
  loss_fn(params, batch, cfg, ctx)            -> (loss, metrics)
  prefill(params, batch, cfg, ctx, max_seq)   -> (caches, logits_last)
  decode_step(params, token, caches, t, ...)  -> (logits, caches)
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.common.config import ModelConfig
from repro.common.parallel import ParallelCtx
from repro.models import blocks
from repro.models.layers import (
    embed,
    embed_init,
    lm_head_init,
    rmsnorm,
    rmsnorm_init,
    unembed,
)
from repro.models.module import Initializer

MOE_AUX_COEF = 0.01
Z_LOSS_COEF = 1e-4


# ------------------------------------------------------------------ init
def init_model(cfg: ModelConfig, key):
    init = Initializer(key, jnp.dtype(cfg.param_dtype))
    embed_init(init.child("embed"), cfg)
    lm_head_init(init.child("head"), cfg)
    rmsnorm_init(init.child("final_norm"), cfg.d_model)
    params, axes = init.collect()
    bp, ba = blocks.stack_init(key, cfg)
    params["blocks"], axes["blocks"] = bp, ba
    if cfg.num_encoder_layers:
        ep, ea = blocks.stack_init(
            jax.random.fold_in(key, 1),
            cfg,
            causal=False,
            n_layers=cfg.num_encoder_layers,
        )
        enc_norm = Initializer(jax.random.fold_in(key, 2),
                               jnp.dtype(cfg.param_dtype))
        rmsnorm_init(enc_norm.child("final_norm"), cfg.d_model)
        np_, na_ = enc_norm.collect()
        params["encoder"] = {"blocks": ep, **np_}
        axes["encoder"] = {"blocks": ea, **na_}
        # decoder blocks get cross-attention
        bp, ba = blocks.stack_init(jax.random.fold_in(key, 3), cfg, cross=True)
        params["blocks"], axes["blocks"] = bp, ba
    return params, axes


def _encode(params, frames, cfg: ModelConfig, ctx: ParallelCtx):
    x, _ = blocks.stack_apply(
        params["encoder"]["blocks"], frames, cfg, ctx, causal=False
    )
    return rmsnorm(params["encoder"]["final_norm"], x, cfg.norm_eps)


def _decoder_inputs(params, batch, cfg: ModelConfig):
    """Token embeddings (+ vision prefix). Returns (x, n_prefix)."""
    x = embed(params["embed"], batch["tokens"], cfg)
    n_prefix = 0
    if cfg.frontend == "vision_stub" and "patches" in batch:
        pfx = batch["patches"].astype(x.dtype)
        x = jnp.concatenate([pfx, x], axis=1)
        n_prefix = pfx.shape[1]
    return x, n_prefix


# --------------------------------------------------------------- forward
def forward(params, batch, cfg: ModelConfig, ctx: ParallelCtx):
    """Teacher-forced forward. batch: tokens (B,S) [+ patches | frames]."""
    x, n_prefix = _decoder_inputs(params, batch, cfg)
    x = ctx.constrain(x, jax.sharding.PartitionSpec(ctx.dp_axes or None))
    enc_out = None
    cross = bool(cfg.num_encoder_layers)
    if cross:
        enc_out = _encode(params, batch["frames"].astype(x.dtype), cfg, ctx)
    x, aux = blocks.stack_apply(
        params["blocks"], x, cfg, ctx, cross=cross, enc_out=enc_out
    )
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if n_prefix:
        x = x[:, n_prefix:, :]
    logits = unembed(params["embed"], x, cfg, params.get("head"))
    return logits, aux


def loss_fn(params, batch, cfg: ModelConfig, ctx: ParallelCtx):
    """Next-token cross-entropy (+ z-loss + MoE aux). tokens: (B, S+1)."""
    tokens = batch["tokens"]
    inputs = dict(batch, tokens=tokens[:, :-1])
    labels = tokens[:, 1:]
    logits, aux = forward(params, inputs, cfg, ctx)
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    label_logit = jnp.take_along_axis(
        logits, labels[..., None], axis=-1
    )[..., 0]
    nll = (logz - label_logit).mean()
    z_loss = Z_LOSS_COEF * (logz**2).mean()
    loss = nll + z_loss + MOE_AUX_COEF * aux
    metrics = {
        "loss": loss,
        "nll": nll,
        "z_loss": z_loss,
        "moe_aux": aux,
        "accuracy": (logits.argmax(-1) == labels).mean(),
    }
    return loss, metrics


# ----------------------------------------------------------------- serve
def prefill(params, batch, cfg: ModelConfig, ctx: ParallelCtx, max_seq: int):
    """Process the prompt, build decode caches, return last-token logits."""
    x, n_prefix = _decoder_inputs(params, batch, cfg)
    enc_out = None
    cross = bool(cfg.num_encoder_layers)
    if cross:
        enc_out = _encode(params, batch["frames"].astype(x.dtype), cfg, ctx)
    x, caches = blocks.stack_prefill(
        params["blocks"], x, 0, cfg, ctx, max_seq + n_prefix,
        cross=cross, enc_out=enc_out,
    )
    x = rmsnorm(params["final_norm"], x[:, -1:, :], cfg.norm_eps)
    logits = unembed(params["embed"], x, cfg, params.get("head"))
    return caches, logits[:, 0, :]


def decode_step(params, token, caches, t, cfg: ModelConfig,
                ctx: ParallelCtx, block_table=None, page_tokens: int = 0):
    """One decode step. token: (B,) int32; t: scalar position shared by the
    batch, or a (B,) vector of per-slot positions (continuous batching).
    With `block_table` (B, n_pages), `caches` is the paged physical
    page-pool layout (`make_paged_decode_caches`) and attention reads and
    writes go through the table."""
    x = embed(params["embed"], token[:, None], cfg)
    cross = bool(cfg.num_encoder_layers)
    x, caches = blocks.stack_decode(
        params["blocks"], caches, x, t, cfg, ctx, cross=cross,
        block_table=block_table, page_tokens=page_tokens,
    )
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params["embed"], x, cfg, params.get("head"))
    return logits[:, 0, :], caches


def prefill_chunk(params, tokens, caches, chunk_idx, cfg: ModelConfig,
                  ctx: ParallelCtx, block_row, page_tokens: int):
    """One page-aligned prompt chunk against the PAGED caches: tokens
    (1, C) at absolute positions [chunk_idx*C, (chunk_idx+1)*C), written
    through `block_row` (1, n_pages) — the prefilling slot's block-table
    row. Returns (last-token logits, caches); the engine uses the logits
    only on the final chunk (the greedy first token). Attention-only
    decoder stacks without frontends/encoders (the engine gates this via
    `runtime.serve.chunked_prefill_supported`)."""
    C = tokens.shape[1]
    c0 = jnp.asarray(chunk_idx, jnp.int32) * C
    x = embed(params["embed"], tokens, cfg)
    x, caches = blocks.stack_prefill_chunk(
        params["blocks"], caches, x, c0, cfg, ctx, block_row, page_tokens
    )
    x = rmsnorm(params["final_norm"], x[:, -1:, :], cfg.norm_eps)
    logits = unembed(params["embed"], x, cfg, params.get("head"))
    return logits[:, 0, :], caches


def make_decode_caches(cfg: ModelConfig, batch: int, max_seq: int,
                       enc_len: int = 0):
    return blocks.init_caches(
        cfg, batch, max_seq,
        cross=bool(cfg.num_encoder_layers), enc_len=enc_len,
    )


def make_paged_decode_caches(cfg: ModelConfig, n_slots: int, max_seq: int,
                             page_tokens: int, enc_len: int = 0,
                             pool_dtype: str = "fp",
                             sz_granularity: str = "page"):
    """Decode caches with self-attention K/V as a physical page pool
    (see blocks.init_paged_caches); the serving engine's paged layout.
    `pool_dtype` ("fp" | "bf16" | "int8") picks the pool payload; int8
    adds the (scale, zero) leaves at `sz_granularity` ("page" default,
    "token" for the speculative-decoding per-token sub-scale pool)."""
    return blocks.init_paged_caches(
        cfg, n_slots, max_seq, page_tokens,
        cross=bool(cfg.num_encoder_layers), enc_len=enc_len,
        pool_dtype=pool_dtype, sz_granularity=sz_granularity,
    )
