"""GQA attention blocks: self-attention (train / prefill / decode) and
cross-attention for the encoder-decoder arch.

The contraction itself is delegated to kernels/flash_attention (prefill) and
kernels/decode_attention (decode), which pick pallas on TPU and the jnp
oracle elsewhere.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.common.config import ModelConfig
from repro.kernels.decode_attention import ops as decode_ops
from repro.kernels.flash_attention import ops as flash_ops
from repro.models.layers import apply_rope
from repro.models.module import Initializer


def attn_init(init: Initializer, cfg: ModelConfig, cross: bool = False):
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    init.param("wq", (d, h, hd), ("embed", "qheads", "head_dim"))
    init.param("wk", (d, kv, hd), ("embed", "kvheads", "head_dim"))
    init.param("wv", (d, kv, hd), ("embed", "kvheads", "head_dim"))
    init.param("wo", (h, hd, d), ("qheads", "head_dim", "embed"))
    if cfg.qkv_bias and not cross:
        init.param("bq", (h, hd), ("qheads", "head_dim"), init="zeros")
        init.param("bk", (kv, hd), ("kvheads", "head_dim"), init="zeros")
        init.param("bv", (kv, hd), ("kvheads", "head_dim"), init="zeros")


def _qkv(params, x, cfg: ModelConfig, positions, rope: bool):
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(dt))
    if "bq" in params:
        q = q + params["bq"].astype(dt)
        k = k + params["bk"].astype(dt)
        v = v + params["bv"].astype(dt)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def sharded_mha(q, k, v, ctx, *, causal: bool = True):
    """Flash attention under shard_map: heads over the tp axis (with local
    GQA group slicing) and batch over dp. Inside shard_map all arrays are
    local, so the triangular scan's traced-index tile loads stay local
    slices — outside it, XLA SPMD 'involuntarily rematerializes' (measured:
    multiple TB of all-gather per step on kimi train_4k).
    """
    from jax.sharding import PartitionSpec as P

    B, S, H, D = q.shape
    KV = k.shape[2]
    R = H // KV
    if ctx is None or ctx.mesh is None:
        return flash_ops.mha(q, k, v, causal=causal)
    tp = ctx.axis_size(ctx.tp_axis)
    dp_ok = ctx.dp_axes and ctx.dp_size > 1 and B % ctx.dp_size == 0
    dp = ctx.dp_axes if dp_ok else None
    H_loc = H // tp if (ctx.tp_axis and tp > 1 and H % tp == 0) else H
    heads_sharded = H_loc != H
    # shard heads only if each shard's heads map onto whole/aligned groups
    if heads_sharded and not (H_loc % R == 0 or R % H_loc == 0):
        heads_sharded = False
        H_loc = H
    h_ax = ctx.tp_axis if heads_sharded else None
    if dp is None and h_ax is None:
        return flash_ops.mha(q, k, v, causal=causal)

    def body(ql, kl, vl):
        if h_ax is not None:
            s = jax.lax.axis_index(h_ax)
            if H_loc >= R:
                g0, G_loc = (s * H_loc) // R, H_loc // R
            else:
                g0, G_loc = (s * H_loc) // R, 1
            kl = jax.lax.dynamic_slice_in_dim(kl, g0, G_loc, axis=2)
            vl = jax.lax.dynamic_slice_in_dim(vl, g0, G_loc, axis=2)
        return flash_ops.mha(ql, kl, vl, causal=causal)

    return jax.shard_map(
        body,
        mesh=ctx.mesh,
        in_specs=(
            P(dp, None, h_ax, None),
            P(dp, None, None, None),
            P(dp, None, None, None),
        ),
        out_specs=P(dp, None, h_ax, None),
        check_vma=False,
    )(q, k, v)


def self_attention(
    params,
    x,                      # (B, S, d)
    cfg: ModelConfig,
    positions=None,         # (B, S) absolute positions
    causal: bool = True,
    rope: bool = True,
    return_kv: bool = False,
    ctx=None,
):
    """Full-sequence self-attention (training / prefill)."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    q, k, v = _qkv(params, x, cfg, positions, rope)
    out = sharded_mha(q, k, v, ctx, causal=causal)
    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
    if return_kv:
        return out, (k, v)
    return out


def _cache_insert(cache, new, t):
    """Insert `new` (B,1,KV,hd) at sequence position(s) t — a scalar shared
    by the batch or a (B,) vector of per-slot positions (continuous
    batching) — via a masked elementwise write. A dynamic-update-slice at a
    traced index on a sequence-SHARDED cache makes XLA SPMD all-gather the
    whole cache (measured: 40 GB of wire per decoded token); the
    iota-compare form partitions with zero communication. A position >= S
    writes nothing (masked slots park their cursor out of range)."""
    S = cache.shape[1]
    t = jnp.asarray(t)
    if t.ndim == 0:
        mask = (jax.lax.iota(jnp.int32, S) == t)[None, :, None, None]
    else:
        mask = (jax.lax.iota(jnp.int32, S)[None, :] == t[:, None])
        mask = mask[:, :, None, None]
    return jnp.where(mask, new.astype(cache.dtype), cache)


def decode_self_attention(
    params,
    x,                      # (B, 1, d) the new token
    cfg: ModelConfig,
    k_cache,                # (B, S_max, KV, hd)
    v_cache,
    t,                      # scalar or (B,): current position(s) / valid len
    rope: bool = True,
):
    """Single-token decode: insert new KV at position t, attend to prefix.

    `t` may be a (B,) vector so that in-flight requests at different depths
    share one fixed-shape decode cell (the serving engine's slot batching);
    the cache length mask and RoPE positions are then per-slot.
    """
    B = x.shape[0]
    t = jnp.asarray(t)
    t_vec = t if t.ndim else jnp.full((B,), t)
    positions = t_vec[:, None]
    q, k, v = _qkv(params, x, cfg, positions, rope)
    k_cache = _cache_insert(k_cache, k, t)
    v_cache = _cache_insert(v_cache, v, t)
    out = decode_ops.decode_mha(q[:, 0], k_cache, v_cache, t_vec + 1)
    out = jnp.einsum("bhk,hkd->bd", out, params["wo"].astype(x.dtype))
    return out[:, None, :], (k_cache, v_cache)


def cross_attention(
    params,
    x,                      # (B, Sq, d) decoder states
    enc_kv,                 # (k, v): (B, Senc, KV, hd) precomputed from encoder
    cfg: ModelConfig,
):
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt))
    k, v = enc_kv
    out = flash_ops.mha(q, k, v, causal=False)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dt))


def encode_cross_kv(params, enc_out, cfg: ModelConfig):
    """Precompute cross-attention K/V from encoder output (once per request)."""
    dt = enc_out.dtype
    k = jnp.einsum("bsd,dhk->bshk", enc_out, params["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, params["wv"].astype(dt))
    return k, v


# ------------------------------------------------------- paged KV cache
def _page_coords(t_vec, block_table, page_tokens: int):
    """Per-slot (physical page, in-range mask, in-page offset) of write
    position(s) `t_vec` through the block table."""
    n_pages = block_table.shape[1]
    pidx = t_vec // page_tokens
    off = t_vec % page_tokens
    in_range = pidx < n_pages
    phys = jnp.take_along_axis(
        block_table, jnp.clip(pidx, 0, n_pages - 1)[:, None], axis=1
    )[:, 0]
    return phys, in_range, off


def paged_cache_insert(pool, new, t, block_table, page_tokens: int):
    """Write one token of K or V per slot into a PHYSICAL page pool.

    pool (P_phys, page, KV, hd); new (B, 1, KV, hd); t scalar or (B,)
    absolute position(s); block_table (B, n_pages) logical->physical page
    map (`KVPager.block_table` layout). The write lands at
    pool[bt[b, t//page], t%page]. Positions past the table (parked slots)
    scatter out of bounds and DROP — the paged twin of `_cache_insert`'s
    masked no-op. Physical pages are uniquely owned, so the scatter never
    collides."""
    B = new.shape[0]
    t = jnp.asarray(t)
    t_vec = (t if t.ndim else jnp.full((B,), t)).astype(jnp.int32)
    phys, in_range, off = _page_coords(t_vec, block_table, page_tokens)
    phys = jnp.where(in_range, phys, pool.shape[0])   # OOB -> dropped
    return pool.at[phys, off].set(new[:, 0].astype(pool.dtype),
                                  mode="drop")


def paged_quant_cache_insert(pool, sz, new, t, block_table,
                             page_tokens: int):
    """int8 twin of `paged_cache_insert`: write one fp token per slot
    into a BLOCK-QUANTIZED page pool. Per-page (scale, zero) quantization
    cannot splice a single int8 row into a page whose range it may move,
    so the insert is a read-modify-write of the slot's tail page:
    dequantize it, zero the rows past the write cursor (fresh free-list
    pages carry stale payload — the garbage must not pollute the range),
    land the token, and requantize the page with a fresh (scale, zero).
    One page per slot per step — the hot tail the pager keeps local —
    and rows whose range did not move requantize onto the identical int8
    grid, so steady pages round-trip bit-stably. Parked positions drop
    exactly like the fp path. Returns (pool, sz).

    With PER-TOKEN sub-scales (`sz` ranked like the pool itself:
    (P_phys, page, KV, 2) — the speculative-decoding hot-page layout)
    the round trip disappears entirely: each token row quantizes against
    its own (scale, zero) over head_dim and lands payload + sz row as a
    pure disjoint scatter, so a verify step's k rows per slot (distinct
    positions, hence distinct (page, offset) targets) never collide and
    nothing already stored is ever re-quantized."""
    from repro.kernels import quant

    B = new.shape[0]
    t = jnp.asarray(t)
    t_vec = (t if t.ndim else jnp.full((B,), t)).astype(jnp.int32)
    phys, in_range, off = _page_coords(t_vec, block_table, page_tokens)
    if sz.ndim == pool.ndim:                 # per-token sub-scales
        q8, tsz = quant.quantize_tokens(new[:, 0].astype(jnp.float32))
        phys_w = jnp.where(in_range, phys, pool.shape[0])  # OOB -> dropped
        pool = pool.at[phys_w, off].set(q8, mode="drop")
        sz = sz.at[phys_w, off].set(tsz, mode="drop")
        return pool, sz
    phys_r = jnp.where(in_range, phys, 0)        # safe gather, discarded
    page_q = pool[phys_r]                        # (B, page, KV, hd) int8
    page_f = quant.dequantize_pages(page_q, sz[phys_r])
    iota = jax.lax.iota(jnp.int32, page_tokens)[None, :, None, None]
    off_b = off[:, None, None, None]
    page_f = jnp.where(iota < off_b, page_f, 0.0)
    page_f = jnp.where(iota == off_b, new.astype(jnp.float32), page_f)
    q8, new_sz = quant.quantize_pages(page_f)
    phys_w = jnp.where(in_range, phys, pool.shape[0])   # OOB -> dropped
    pool = pool.at[phys_w].set(q8, mode="drop")
    sz = sz.at[phys_w].set(new_sz, mode="drop")
    return pool, sz


def paged_chunk_insert(pool, new, c0, block_row, page_tokens: int):
    """Write a page-aligned CHUNK of K or V through the block table.

    pool (P_phys, page, KV, hd); new (1, C, KV, hd) with C a multiple of
    `page_tokens`; c0 (traced) chunk start, also page-aligned; block_row
    (1, n_pages) the prefilling slot's block-table row. Whole pages are
    scattered at once — the chunked-prefill fast path."""
    _, C, KV, hd = new.shape
    n_wp = C // page_tokens
    p0 = jnp.asarray(c0, jnp.int32) // page_tokens
    phys = jax.lax.dynamic_slice(block_row, (jnp.int32(0), p0),
                                 (1, n_wp))[0]        # (n_wp,)
    tiles = new[0].reshape(n_wp, page_tokens, KV, hd)
    return pool.at[phys].set(tiles.astype(pool.dtype))


def paged_decode_self_attention(
    params,
    x,                      # (B, 1, d) the new token
    cfg: ModelConfig,
    cache,                  # attention cache dict: "k"/"v" physical page
    #                         pools (P_phys, page, KV, hd), plus
    #                         "k_sz"/"v_sz" (P_phys, KV, 2) when int8
    t,                      # scalar or (B,): current position(s)
    block_table,            # (B, n_pages) int32
    page_tokens: int,
    rope: bool = True,
):
    """Single-token decode against the paged cache: insert new KV through
    the block table, gather-attend via the paged decode kernel. Same
    contract as `decode_self_attention` — per-slot `t`, parked positions
    write nothing — but the cache IS the physical page pool the serving
    pager allocates from, so tier placement is real at the data layout.
    Block-quantized pools (the "k_sz"/"v_sz" leaves) quantize on insert
    and dequantize inside the kernel. Returns (out, cache_updates)."""
    B = x.shape[0]
    t = jnp.asarray(t)
    t_vec = t if t.ndim else jnp.full((B,), t)
    positions = t_vec[:, None]
    q, k, v = _qkv(params, x, cfg, positions, rope)
    quantized = "k_sz" in cache
    if quantized:
        k_pool, k_sz = paged_quant_cache_insert(
            cache["k"], cache["k_sz"], k, t_vec, block_table, page_tokens)
        v_pool, v_sz = paged_quant_cache_insert(
            cache["v"], cache["v_sz"], v, t_vec, block_table, page_tokens)
        out = decode_ops.paged_decode_mha(
            q[:, 0], k_pool, v_pool, block_table, t_vec + 1,
            k_sz=k_sz, v_sz=v_sz,
        )
        updates = {"k": k_pool, "v": v_pool, "k_sz": k_sz, "v_sz": v_sz}
    else:
        k_pool = paged_cache_insert(cache["k"], k, t_vec, block_table,
                                    page_tokens)
        v_pool = paged_cache_insert(cache["v"], v, t_vec, block_table,
                                    page_tokens)
        out = decode_ops.paged_decode_mha(
            q[:, 0], k_pool, v_pool, block_table, t_vec + 1
        )
        updates = {"k": k_pool, "v": v_pool}
    out = jnp.einsum("bhk,hkd->bd", out, params["wo"].astype(x.dtype))
    return out[:, None, :], updates


def paged_prefill_chunk_attention(
    params,
    x,                      # (1, C, d) one chunk of one request's prompt
    cfg: ModelConfig,
    cache,                  # attention cache dict (see
    #                         `paged_decode_self_attention`)
    c0,                     # (traced) absolute position of the chunk start
    block_row,              # (1, n_pages) the slot's block-table row
    page_tokens: int,
    rope: bool = True,
):
    """One prompt chunk against the paged cache via the FUSED
    insert+attend kernel: the chunk's K/V (int8 pools: pre-quantized
    payload + per-page (scale, zero) rows — elementwise math, no
    scatter) goes into the paged-prefill kernel as an operand and lands
    in the pool through `input_output_aliases` while the same pass
    flash-attends over everything prefilled so far. The standalone jnp
    page-scatter of the chunk's K/V — one full extra read+write of the
    chunk through HBM per layer — does not exist on the kernel backends
    (the reference backend runs the unfused oracle). C and c0 must be
    page-aligned (the engine enforces `prefill_chunk % page_tokens ==
    0`). Returns (out, cache_updates)."""
    B, C, _ = x.shape
    c0 = jnp.asarray(c0, jnp.int32)
    positions = c0 + jnp.broadcast_to(jnp.arange(C)[None, :], (B, C))
    q, k, v = _qkv(params, x, cfg, positions, rope)
    quantized = "k_sz" in cache
    if quantized and cache["k_sz"].ndim == cache["k"].ndim:
        # per-token sub-scale pool (speculative decoding): quantize each
        # chunk token row against its own (scale, zero), whole-page
        # scatter payload + sz rows, then gather-attend — the fused
        # insert kernel stays per-page-only, and chunked prefill is off
        # the decode hot loop so the unfused write is acceptable here
        from repro.kernels import quant

        k8, ksz = quant.quantize_tokens(k.astype(jnp.float32))
        v8, vsz = quant.quantize_tokens(v.astype(jnp.float32))
        k_pool = paged_chunk_insert(cache["k"], k8, c0, block_row,
                                    page_tokens)
        v_pool = paged_chunk_insert(cache["v"], v8, c0, block_row,
                                    page_tokens)
        k_sz = paged_chunk_insert(cache["k_sz"], ksz, c0, block_row,
                                  page_tokens)
        v_sz = paged_chunk_insert(cache["v_sz"], vsz, c0, block_row,
                                  page_tokens)
        out = flash_ops.paged_prefill_mha(
            q, k_pool, v_pool, block_row, c0, k_sz=k_sz, v_sz=v_sz,
        )
        updates = {"k": k_pool, "v": v_pool, "k_sz": k_sz, "v_sz": v_sz}
    elif quantized:
        from repro.kernels import quant

        n_wp = C // page_tokens
        KV, hd = k.shape[2], k.shape[3]
        k8, ksz = quant.quantize_pages(
            k.reshape(B, n_wp, page_tokens, KV, hd))
        v8, vsz = quant.quantize_pages(
            v.reshape(B, n_wp, page_tokens, KV, hd))
        out, k_pool, v_pool, k_sz, v_sz = flash_ops.paged_prefill_insert_mha_q8(
            q, cache["k"], cache["v"], cache["k_sz"], cache["v_sz"],
            k8.reshape(B, C, KV, hd), v8.reshape(B, C, KV, hd),
            ksz, vsz, block_row, c0,
        )
        updates = {"k": k_pool, "v": v_pool, "k_sz": k_sz, "v_sz": v_sz}
    else:
        out, k_pool, v_pool = flash_ops.paged_prefill_insert_mha(
            q, cache["k"], cache["v"], k, v, block_row, c0,
        )
        updates = {"k": k_pool, "v": v_pool}
    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
    return out, updates


def decode_cross_attention(params, x, cross_kv, cfg: ModelConfig):
    """Decode-time cross-attention against the fixed encoder K/V."""
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt))
    k, v = cross_kv
    S_enc = k.shape[1]
    out = decode_ops.decode_mha(q[:, 0], k, v, S_enc)
    return jnp.einsum("bhk,hkd->bd", out, params["wo"].astype(dt))[:, None, :]


def make_kv_cache(cfg: ModelConfig, batch: int, max_seq: int, n_layers: int,
                  dtype=None):
    """Stacked KV cache for the attention layers of a model."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    shape = (n_layers, batch, max_seq, cfg.num_kv_heads, cfg.head_dim)
    return jnp.zeros(shape, dtype), jnp.zeros(shape, dtype)
