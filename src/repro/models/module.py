"""Minimal functional module system.

Params are plain pytrees of `jax.Array`. Alongside every params tree the init
functions build a *matching* tree of logical-axis tuples (one string-or-None
per array dim) which the sharding rules (runtime/sharding.py) and the tier
engine (core/placement.py) consume. Keeping metadata out of the value tree
keeps jit/scan/optimizer code trivial.
"""

from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

_SHAPE_ONLY = contextvars.ContextVar("shape_only", default=False)


@contextlib.contextmanager
def shape_mode():
    """Initializers produce ShapeDtypeStructs — allocation-free abstract init
    (the dry-run path)."""
    tok = _SHAPE_ONLY.set(True)
    try:
        yield
    finally:
        _SHAPE_ONLY.reset(tok)


def shape_mode_active() -> bool:
    return _SHAPE_ONLY.get()


@dataclasses.dataclass
class ParamSpec:
    """Logical description of one parameter tensor."""

    axes: tuple[Optional[str], ...]

    def __repr__(self):
        return f"ParamSpec{self.axes}"


class Initializer:
    """Collects (value, axes) pairs during init.

    Usage:
        init = Initializer(key, dtype)
        w = init.param("wq", (d, h, hd), ("embed", "qheads", "head_dim"))
        ...
        params, axes = init.collect()
    """

    def __init__(self, key: jax.Array, dtype=jnp.float32):
        self._key = key
        self._dtype = dtype
        self._values: dict = {}
        self._axes: dict = {}

    def _next_key(self):
        self._key, sub = jax.random.split(self._key)
        return sub

    def param(
        self,
        name: str,
        shape: tuple[int, ...],
        axes: tuple[Optional[str], ...],
        init: str = "normal",
        scale: Optional[float] = None,
    ) -> jax.Array:
        assert len(shape) == len(axes), (name, shape, axes)
        if shape_mode_active():
            v = jax.ShapeDtypeStruct(shape, self._dtype)
            self._values[name] = v
            self._axes[name] = ParamSpec(tuple(axes))
            return v
        if init == "zeros":
            v = jnp.zeros(shape, self._dtype)
        elif init == "ones":
            v = jnp.ones(shape, self._dtype)
        elif init == "normal":
            fan_in = shape[0] if shape else 1
            s = scale if scale is not None else 1.0 / np.sqrt(max(fan_in, 1))
            v = jax.random.normal(self._next_key(), shape, self._dtype) * s
        elif init == "embedding":
            v = jax.random.normal(self._next_key(), shape, self._dtype) * (
                scale if scale is not None else 0.02
            )
        else:
            raise ValueError(f"unknown init {init}")
        self._values[name] = v
        self._axes[name] = ParamSpec(tuple(axes))
        return v

    def child(self, name: str):
        sub = Initializer(self._next_key(), self._dtype)
        self._values[name] = sub._values
        self._axes[name] = sub._axes
        return sub

    def collect(self):
        return self._values, self._axes


def stack_inits(init_fn: Callable, key: jax.Array, n: int):
    """vmap an init over a leading 'layers' dim; axes get 'layers' prepended."""
    if shape_mode_active():
        values, axes = init_fn(key)
        params = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype), values
        )
    else:
        keys = jax.random.split(key, n)
        params = jax.vmap(lambda k: init_fn(k)[0])(keys)
        with shape_mode():
            _, axes = init_fn(key)
    axes = jax.tree.map(
        lambda s: ParamSpec(("layers",) + s.axes),
        axes,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )
    return params, axes


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def axes_tree_map(fn, axes_tree):
    return jax.tree.map(fn, axes_tree, is_leaf=is_spec)
