"""Mixture-of-Experts FFN with expert parallelism.

Two execution paths with identical math (up to capacity dropping):

* `moe_dense` — computes every expert for every token and combines with the
  top-k gate weights. Exact; O(E) compute; used as the oracle and for tiny
  reduced configs.
* `moe_ep` — production path: shard_map over the EP axis. Tokens are routed
  with a capacity-bounded sort-based dispatch, exchanged with all_to_all,
  processed by the local expert shard (optionally FSDP-gathering the expert
  weights over the fsdp axis), and combined back. This is the DeepSeek-style
  EP schedule expressed in jax.lax collectives.

The router runs in fp32; gates are renormalized over the top-k.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.common.config import ModelConfig
from repro.common.parallel import ParallelCtx
from repro.models.module import Initializer


def moe_init(init: Initializer, cfg: ModelConfig):
    d, e, ff = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    init.param("router", (d, e), ("embed", "experts"))
    if cfg.act in ("swiglu", "geglu"):
        init.param("w_gate", (e, d, ff), ("experts", "embed", "moe_ff"))
    init.param("w_up", (e, d, ff), ("experts", "embed", "moe_ff"))
    init.param("w_down", (e, ff, d), ("experts", "moe_ff", "embed"))


def _route(params, x32, cfg: ModelConfig):
    """Router logits -> (gates (T,k) f32, expert ids (T,k) i32, probs (T,E))."""
    logits = x32 @ params["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, cfg.experts_per_token)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    return gates, idx, probs


def _expert_ffn(w, h, cfg: ModelConfig):
    """Batched expert FFN: h (E, C, d) -> (E, C, d)."""
    dt = h.dtype
    up = jnp.einsum("ecd,edf->ecf", h, w["w_up"].astype(dt))
    if cfg.act in ("swiglu", "geglu"):
        gate = jnp.einsum("ecd,edf->ecf", h, w["w_gate"].astype(dt))
        nl = jax.nn.silu if cfg.act == "swiglu" else jax.nn.gelu
        a = nl(gate) * up
    else:
        a = jax.nn.gelu(up)
    return jnp.einsum("ecf,efd->ecd", a, w["w_down"].astype(dt))


def _aux_loss(probs, idx, cfg: ModelConfig):
    """Switch-style load-balance loss: E * sum_e f_e * p_e."""
    E = cfg.num_experts
    f = jnp.mean(
        jax.nn.one_hot(idx, E, dtype=jnp.float32).sum(axis=1), axis=0
    ) / cfg.experts_per_token
    p = probs.mean(axis=0)
    return E * jnp.sum(f * p)


# ------------------------------------------------------------------ dense
def moe_dense(params, x, cfg: ModelConfig):
    """Oracle path: every expert computed for every token."""
    B, S, d = x.shape
    xf = x.reshape(-1, d)
    gates, idx, probs = _route(params, xf.astype(jnp.float32), cfg)
    combine = jnp.zeros((xf.shape[0], cfg.num_experts), jnp.float32)
    combine = jax.vmap(lambda c, i, g: c.at[i].add(g))(combine, idx, gates)
    # y_t = sum_e combine[t,e] * f_e(x_t): every expert applied to every token
    out = _expert_ffn(
        params,
        jnp.broadcast_to(xf[None], (cfg.num_experts,) + xf.shape),
        cfg,
    )                                                           # (E,T,d)
    y = jnp.einsum("etd,te->td", out.astype(jnp.float32),
                   combine).astype(x.dtype)
    aux = _aux_loss(probs, idx, cfg)
    return y.reshape(B, S, d), aux


# ------------------------------------------------------------------ EP
def _capacity(tokens_local: int, cfg: ModelConfig) -> int:
    c = math.ceil(
        tokens_local * cfg.experts_per_token * cfg.capacity_factor
        / cfg.num_experts
    )
    return max(int(c), 4)


def _moe_local(params, x, cfg: ModelConfig, ep_axis: Optional[str],
               fsdp_axis: Optional[str], ep_size: int, all_axes,
               fsdp_mode: str = "rowcol"):
    """Per-shard body (runs under shard_map). x: (T_loc, d).

    fsdp_mode controls how the fsdp-sharded expert ff dim is handled:
      "gather" — all-gather the weights per layer (classic FSDP). Wire cost
                 scales with WEIGHT bytes x microbatches.
      "rowcol" — column/row-parallel compute on the ff shard + one psum of
                 the expert OUTPUT over the fsdp axis. Wire cost scales
                 with TOKEN bytes — for MoE layers (huge weights, modest
                 per-expert token counts) this is the winning schedule
                 (kimi train_4k: 43.7s -> measured below in §Perf).
    """
    T, d = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    E_loc = E // ep_size
    C = _capacity(T, cfg)

    w = params
    rowcol = fsdp_axis is not None and fsdp_mode == "rowcol"
    if fsdp_axis is not None and not rowcol:
        w = dict(params)
        for name in ("w_gate", "w_up", "w_down"):
            if name in params:
                # FSDP: weights arrive sharded on their ff dim; gather/layer
                # (cast to compute dtype FIRST: gather bf16, not fp32)
                dim = 2 if name != "w_down" else 1
                w[name] = jax.lax.all_gather(
                    params[name].astype(jnp.dtype(cfg.dtype)),
                    fsdp_axis, axis=dim, tiled=True,
                )

    gates, idx, probs = _route(w, x.astype(jnp.float32), cfg)

    flat_e = idx.reshape(-1)                          # (T*k,)
    flat_t = jnp.repeat(jnp.arange(T), k)
    flat_g = gates.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]
    first = jnp.searchsorted(se, jnp.arange(E))
    pos = jnp.arange(T * k) - first[se]
    keep = pos < C
    slot = se * C + jnp.where(keep, pos, 0)

    send = jnp.zeros((E * C, d), x.dtype)
    send = send.at[slot].add(jnp.where(keep[:, None], x[st], 0))
    send = send.reshape(ep_size, E_loc, C, d)

    if ep_axis is not None and ep_size > 1:
        recv = jax.lax.all_to_all(send, ep_axis, split_axis=0, concat_axis=0)
    else:
        recv = send
    h = recv.transpose(1, 0, 2, 3).reshape(E_loc, ep_size * C, d)

    out = _expert_ffn(w, h, cfg)                      # (E_loc, M*C, d)
    if rowcol:
        # row-parallel epilogue: partial sums over the sharded ff dim
        out = jax.lax.psum(out, fsdp_axis)

    out = out.reshape(E_loc, ep_size, C, d).transpose(1, 0, 2, 3)
    if ep_axis is not None and ep_size > 1:
        back = jax.lax.all_to_all(out, ep_axis, split_axis=0, concat_axis=0)
    else:
        back = out
    flat_back = back.reshape(E * C, d)

    contrib = flat_back[slot].astype(jnp.float32) * sg[:, None]
    contrib = jnp.where(keep[:, None], contrib, 0.0)
    y = jnp.zeros((T, d), jnp.float32).at[st].add(contrib)

    # load-balance aux over the GLOBAL token set: psum the sufficient
    # statistics (dispatch counts, router prob sums, token count) — the loss
    # is not linear over token partitions, so pmean of per-shard losses
    # would NOT match the dense oracle
    counts = jax.nn.one_hot(idx, E, dtype=jnp.float32).sum(axis=(0, 1))
    p_sum = probs.sum(axis=0)
    t_cnt = jnp.asarray(T, jnp.float32)
    if all_axes:
        counts = jax.lax.psum(counts, all_axes)
        p_sum = jax.lax.psum(p_sum, all_axes)
        t_cnt = jax.lax.psum(t_cnt, all_axes)
    f = counts / (t_cnt * k)
    p = p_sum / t_cnt
    aux = E * jnp.sum(f * p)
    return y.astype(x.dtype), aux


def moe_ep(params, x, cfg: ModelConfig, ctx: ParallelCtx):
    """Expert-parallel MoE. x: (B, S, d). Returns (y, aux_loss)."""
    if ctx.mesh is None:
        return moe_dense(params, x, cfg)
    B, S, d = x.shape
    ep_axis = ctx.tp_axis
    ep_size = ctx.axis_size(ep_axis)
    assert cfg.num_experts % max(ep_size, 1) == 0, (cfg.num_experts, ep_size)

    seq_axis = (
        ep_axis
        if (ctx.shard_seq_moe and ep_axis and S % ep_size == 0 and S >= ep_size)
        else None
    )
    dp = (
        ctx.dp_axes
        if (ctx.dp_axes and B % max(ctx.dp_size, 1) == 0 and ctx.dp_size > 1)
        else None
    )
    x_spec = P(dp, seq_axis, None)
    w_specs = {
        "router": P(None, None),
        "w_up": P(ep_axis, None, ctx.fsdp_axis),
        "w_down": P(ep_axis, ctx.fsdp_axis, None),
    }
    if "w_gate" in params:
        w_specs["w_gate"] = P(ep_axis, None, ctx.fsdp_axis)

    def body(w, xs):
        bs, ss = xs.shape[0], xs.shape[1]
        y, aux = _moe_local(
            w, xs.reshape(-1, d), cfg, ep_axis, ctx.fsdp_axis, ep_size,
            ctx.all_axes, fsdp_mode=ctx.moe_fsdp_mode,
        )
        return y.reshape(bs, ss, d), aux

    y, aux = jax.shard_map(
        body,
        mesh=ctx.mesh,
        in_specs=({k: w_specs[k] for k in params}, x_spec),
        out_specs=(x_spec, P()),
        check_vma=False,
    )(params, x)
    return y, aux
