"""Shared layers: RMSNorm, embedding, RoPE, dense MLP."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.common.config import ModelConfig
from repro.models.module import Initializer


# ---------------------------------------------------------------- norms
def rmsnorm_init(init: Initializer, d: int, name: str = "scale"):
    init.param(name, (d,), ("embed",), init="ones")


def rmsnorm(params, x, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------- embedding
def embed_init(init: Initializer, cfg: ModelConfig):
    init.param(
        "embedding", (cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
        init="embedding",
    )


def embed(params, tokens, cfg: ModelConfig):
    out = jnp.take(params["embedding"], tokens, axis=0)
    return out.astype(jnp.dtype(cfg.dtype)) * jnp.sqrt(
        jnp.asarray(cfg.d_model, jnp.dtype(cfg.dtype))
    )


def unembed(params, x, cfg: ModelConfig, head_params=None):
    """Project to vocab logits. Uses tied embedding when configured."""
    if cfg.tie_embeddings:
        w = params["embedding"]  # (V, d)
        return jnp.einsum("bsd,vd->bsv", x, w.astype(x.dtype))
    w = head_params["lm_head"]   # (d, V)
    return jnp.einsum("bsd,dv->bsv", x, w.astype(x.dtype))


def lm_head_init(init: Initializer, cfg: ModelConfig):
    if not cfg.tie_embeddings:
        init.param(
            "lm_head", (cfg.d_model, cfg.vocab_size), ("embed", "vocab"),
            init="normal",
        )


# ---------------------------------------------------------------- RoPE
def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta: float):
    """x (..., S, H, D) with positions (..., S)."""
    D = x.shape[-1]
    freqs = rope_freqs(D, theta)                        # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (...,S,D/2)
    cos = jnp.cos(angles)[..., None, :]                 # (...,S,1,D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    rx1 = x1 * cos - x2 * sin
    rx2 = x2 * cos + x1 * sin
    return jnp.concatenate([rx1, rx2], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------- dense MLP
GATED_ACTS = ("swiglu", "geglu")


def mlp_init(init: Initializer, cfg: ModelConfig, d_ff: Optional[int] = None):
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    if cfg.act in GATED_ACTS:
        init.param("w_gate", (d, ff), ("embed", "ff"))
    init.param("w_up", (d, ff), ("embed", "ff"))
    init.param("w_down", (ff, d), ("ff", "embed"))


def mlp(params, x, cfg: ModelConfig):
    dt = x.dtype
    up = jnp.einsum("bsd,df->bsf", x, params["w_up"].astype(dt))
    if cfg.act in GATED_ACTS:
        gate = jnp.einsum("bsd,df->bsf", x, params["w_gate"].astype(dt))
        nl = jax.nn.silu if cfg.act == "swiglu" else jax.nn.gelu
        h = nl(gate) * up
    else:
        h = jax.nn.gelu(up)
    return jnp.einsum("bsf,fd->bsd", h, params["w_down"].astype(dt))
