"""Interference-aware scheduling (paper §7.2), from toy to rack scale.

Two layers:

* `scheduler` — the original single-pool Fig 13 reproduction: `Job`,
  `RandomScheduler` / `InterferenceAwareScheduler` one-shot placement and
  the `simulate_colocation` Monte-Carlo with an *assumed* background LoI
  range. Kept as the minimal, didactic model.

* the rack-scale subsystem — `cluster` (racks × pools × node slots, each
  pool one shared-link contention domain), `workload` (job streams whose
  profiles are computed at submission, per the paper's SLURM proposal),
  `policies` (FCFS / random / interference-aware / corridor bin-packing
  behind the `Policy` protocol) and `simulator` (event-driven engine whose
  background LoI is *derived* from actual co-residents via
  `core.interference` instead of assumed). See `simulator`'s module
  docstring for the event model.
"""

from repro.sched.scheduler import (  # noqa: F401
    Job,
    InterferenceAwareScheduler,
    RandomScheduler,
    simulate_colocation,
)
from repro.sched.cluster import (  # noqa: F401
    Cluster,
    ClusterSpec,
    Pool,
    Rack,
    build_cluster,
)
from repro.sched.policies import (  # noqa: F401
    DEFAULT_POLICIES,
    CorridorBinPackPolicy,
    FCFSPolicy,
    InterferenceAwarePolicy,
    Policy,
    RandomPolicy,
    make_policy,
)
from repro.sched.simulator import SimResult, run_policies, simulate  # noqa: F401
from repro.sched.workload import (  # noqa: F401
    TraceJob,
    catalog_stream,
    profile_with_injected_loi,
    rescale_load,
    synthetic_profile,
    synthetic_stream,
)
