from repro.sched.scheduler import (  # noqa
    Job,
    InterferenceAwareScheduler,
    RandomScheduler,
    simulate_colocation,
)
