"""Pluggable placement policies for the rack-scale simulator.

All policies implement the `Policy` protocol: given a job, the cluster and
the simulation clock, return the pool to place it on (or None to leave it
queued). Policies only see submission-time metrics (injected LoI, IC,
sensitivity curve) — never the future of the trace — matching the paper's
§7.2 proposal of shipping the level-3 metrics to the resource manager.

  fcfs     — first open pool in id order; the no-information baseline.
  random   — uniformly random open pool; the paper's Fig 13 baseline.
  aware    — interference-aware (paper §7.2): minimize predicted marginal
             slowdown — the job's own degradation at the pool's current
             LoI plus the degradation it inflicts on the residents.
  binpack  — pool-aware best-fit-decreasing on the R_bw corridor: each
             pool has an aggregate injected-LoI budget (its share of link
             bandwidth it can absorb before queueing explodes); place the
             job in the open pool with the smallest nonnegative headroom
             after placement, falling back to max headroom when nothing
             fits the corridor.
"""

from __future__ import annotations

from typing import List, Optional, Protocol, runtime_checkable

import numpy as np

from repro.core.interference import corridor_budget
from repro.sched.cluster import Cluster, Pool


@runtime_checkable
class Policy(Protocol):
    name: str

    def select(self, job, cluster: Cluster, now: float) -> Optional[Pool]:
        """Pick an open pool for `job`, or None to keep it queued."""
        ...

    def reset(self) -> None:
        """Clear per-run state (e.g. reseed the rng) before a fresh run."""
        ...


class FCFSPolicy:
    """First open pool in id order (packs the cluster front to back)."""

    name = "fcfs"

    def select(self, job, cluster: Cluster, now: float) -> Optional[Pool]:
        for p in cluster.pools:
            if p.is_open:
                return p
        return None

    def reset(self) -> None:
        pass


class RandomPolicy:
    """Uniformly random open pool — the paper's baseline scheduler."""

    name = "random"

    def __init__(self, seed: int = 0):
        self.seed = seed
        self.rng = np.random.default_rng(seed)

    def select(self, job, cluster: Cluster, now: float) -> Optional[Pool]:
        open_pools = cluster.open_pools()
        if not open_pools:
            return None
        return open_pools[int(self.rng.integers(len(open_pools)))]

    def reset(self) -> None:
        self.rng = np.random.default_rng(self.seed)


def marginal_colocation_cost(pool, job) -> float:
    """Predicted marginal slowdown of adding `job` to `pool`: the job's
    own degradation at the pool's current aggregate LoI plus the increase
    in every resident's degradation once the job's injected LoI joins the
    link. Duck-typed over both the rack-scale `cluster.Pool` and the toy
    `scheduler.Pool` (needs `pool.jobs` + `pool.background_loi_for`, and
    `.injected_loi` / `.sensitivity` on jobs)."""
    bg_for_new = pool.background_loi_for(job)   # job is not resident yet
    cost = 1.0 / max(job.sensitivity(bg_for_new), 1e-6) - 1.0
    for res in pool.jobs:
        bg_now = pool.background_loi_for(res)
        bg_with = min(1.0, bg_now + job.injected_loi)
        cost += (
            1.0 / max(res.sensitivity(bg_with), 1e-6)
            - 1.0 / max(res.sensitivity(bg_now), 1e-6)
        )
    return cost


class InterferenceAwarePolicy:
    """Greedy minimum-marginal-slowdown placement (paper §7.2).

    Uses `marginal_colocation_cost`: high-IC jobs steer away from pools
    holding high-sensitivity residents and vice versa.
    """

    name = "aware"

    def select(self, job, cluster: Cluster, now: float) -> Optional[Pool]:
        open_pools = cluster.open_pools()
        if not open_pools:
            return None
        return min(open_pools,
                   key=lambda p: marginal_colocation_cost(p, job))

    def reset(self) -> None:
        pass


class CorridorBinPackPolicy:
    """Best-fit bin-packing on the pool's bandwidth corridor.

    The corridor budget is the aggregate injected LoI a pool link absorbs
    before M/D/1 queueing departs the linear regime. It is DERIVED from the
    pool topology by `core.interference.corridor_budget` — the M/D/1 knee
    utilization discounted by `TierTopology.r_bw_pool` (~0.59 on the
    emulated v5e pool) — rather than hard-coded; pass `loi_budget` to
    override (trace studies / tests). Placement is classic best-fit: the
    open pool whose post-placement headroom is smallest but still
    nonnegative; if the job fits no corridor, the pool with maximum
    headroom (least overflow) — capacity corridors (R_cap) are enforced by
    the node-slot capacity itself.
    """

    name = "binpack"

    def __init__(self, loi_budget: Optional[float] = None, topo=None):
        self.loi_budget = (
            loi_budget if loi_budget is not None else corridor_budget(topo)
        )

    def select(self, job, cluster: Cluster, now: float) -> Optional[Pool]:
        open_pools = cluster.open_pools()
        if not open_pools:
            return None
        headrooms = [
            self.loi_budget - p.total_injected_loi() - job.injected_loi
            for p in open_pools
        ]
        fitting = [(h, i) for i, h in enumerate(headrooms) if h >= 0.0]
        if fitting:
            _, idx = min(fitting)           # tightest fit
        else:
            idx = int(np.argmax(headrooms))  # least overflow
        return open_pools[idx]

    def reset(self) -> None:
        pass


def make_policy(name: str, *, seed: int = 0, **kwargs) -> Policy:
    """Factory used by benchmarks/CLI: fcfs | random | aware | binpack."""
    table = {
        "fcfs": lambda: FCFSPolicy(),
        "random": lambda: RandomPolicy(seed=seed),
        "aware": lambda: InterferenceAwarePolicy(),
        "binpack": lambda: CorridorBinPackPolicy(**kwargs),
    }
    if name not in table:
        raise ValueError(f"unknown policy {name!r}; one of {sorted(table)}")
    return table[name]()


DEFAULT_POLICIES: List[str] = ["fcfs", "random", "aware", "binpack"]
