"""Event-driven rack-scale co-location simulator (paper §7.2 at scale).

Event model
-----------
The simulator advances a continuous clock between two event kinds:

* **arrival** — the next job of the trace submits; the policy picks an
  open pool (or the job joins the FIFO backlog queue);
* **completion** — the running job with the earliest projected finish
  retires, freeing a node slot; backlogged jobs are then re-offered to
  the policy in FIFO order.

Between events nothing changes: each pool's membership — hence each
resident's background LoI (`core.interference.background_lois` over the
residents' injected LoI) and progress rate (`core.interference.
progress_rates`) — is constant, so each running job consumes its remaining
isolated work linearly at `rate = sensitivity(bg_loi)` ∈ (0, 1]. An event
only perturbs the pools it touches; rates are recomputed per affected pool
with vectorized numpy over that pool's residents, and the per-step
slowdown accounting is O(running jobs) per event. 10k-job traces simulate
in a couple of seconds.

Mapping to the paper: each run of a job between membership changes is one
Fig 13 "interval" — except the background LoI is not resampled from a
uniform range, it is *derived* from who the scheduler actually co-located
on the pool. The aware policy reproduces the paper's result (lower
variance, lower tail) as an emergent property instead of an assumed
0-20% LoI cap.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.interference import background_lois, progress_rates
from repro.sched.cluster import Cluster, ClusterSpec
from repro.sched.policies import Policy, make_policy
from repro.sched.workload import TraceJob


@dataclasses.dataclass
class SimResult:
    """Per-job accounting (arrays are indexed like the input job list)."""

    policy: str
    arrival: np.ndarray
    start: np.ndarray
    finish: np.ndarray
    work: np.ndarray
    pool_of: np.ndarray          # pool id each job ran on
    peak_occupancy: np.ndarray   # per pool, max concurrent residents
    n_events: int

    @property
    def wait(self) -> np.ndarray:
        return self.start - self.arrival

    @property
    def slowdown(self) -> np.ndarray:
        """Service slowdown: observed runtime / isolated runtime (>= 1)."""
        return (self.finish - self.start) / self.work

    @property
    def stretch(self) -> np.ndarray:
        """End-to-end stretch including queueing delay."""
        return (self.finish - self.arrival) / self.work

    @property
    def makespan(self) -> float:
        return float(self.finish.max() - self.arrival.min())

    def summary(self) -> Dict[str, float]:
        s = self.slowdown
        return {
            "policy": self.policy,
            "n_jobs": int(len(self.work)),
            "mean_slowdown": float(s.mean()),
            "var_slowdown": float(s.var()),
            "p95_slowdown": float(np.percentile(s, 95)),
            "max_slowdown": float(s.max()),
            "mean_wait_s": float(self.wait.mean()),
            "mean_stretch": float(self.stretch.mean()),
            "makespan_s": self.makespan,
            "events": int(self.n_events),
        }


def simulate(jobs: Sequence[TraceJob], cluster: Cluster, policy: Policy,
             *, reset: bool = True) -> SimResult:
    """Run `jobs` (any order; sorted by arrival internally) through
    `cluster` under `policy`. Deterministic for a fixed (trace, policy
    seed) pair."""
    n = len(jobs)
    if n == 0:
        raise ValueError("empty trace")
    if cluster.total_capacity < 1:
        raise ValueError("cluster has no node slots")
    if reset:
        cluster.reset()
        policy.reset()

    arrival = np.array([j.arrival for j in jobs], dtype=np.float64)
    work = np.array([j.work for j in jobs], dtype=np.float64)
    inj = np.array([j.injected_loi for j in jobs], dtype=np.float64)
    t_pool = np.array([j.t_pool for j in jobs], dtype=np.float64)
    t_local = np.array([j.t_local for j in jobs], dtype=np.float64)
    t_comp = np.array([j.t_compute for j in jobs], dtype=np.float64)
    if np.any(work <= 0):
        raise ValueError("every job needs positive work")

    remaining = work.copy()
    rate = np.zeros(n)
    start = np.full(n, np.nan)
    finish = np.full(n, np.nan)
    pool_of = np.full(n, -1, dtype=np.int64)

    n_pools = len(cluster.pools)
    members: List[List[int]] = [[] for _ in range(n_pools)]
    peak_occ = np.zeros(n_pools, dtype=np.int64)

    order = list(np.argsort(arrival, kind="stable"))
    i_arr = 0
    running: List[int] = []
    backlog: collections.deque = collections.deque()
    now = 0.0
    done = 0
    n_events = 0

    def place(idx: int, pool, t: float) -> None:
        pool.add(jobs[idx])
        pid = pool.pool_id
        members[pid].append(idx)
        assert len(members[pid]) <= pool.capacity, "capacity overrun"
        peak_occ[pid] = max(peak_occ[pid], len(members[pid]))
        pool_of[idx] = pid
        start[idx] = t
        running.append(idx)

    def refresh_rates(pid: int) -> None:
        idx = members[pid]
        if not idx:
            return
        ia = np.asarray(idx, dtype=np.int64)
        bg = background_lois(inj[ia])
        rate[ia] = progress_rates(t_pool[ia], t_local[ia], t_comp[ia], bg)

    while done < n:
        t_arr = arrival[order[i_arr]] if i_arr < n else np.inf
        if running:
            ra = np.asarray(running, dtype=np.int64)
            t_fins = now + remaining[ra] / rate[ra]
            k = int(np.argmin(t_fins))
            t_fin, j_fin = float(t_fins[k]), int(ra[k])
        else:
            t_fin, j_fin = np.inf, -1
        if not np.isfinite(min(t_arr, t_fin)):
            raise RuntimeError(
                "deadlock: backlog non-empty but nothing runs or arrives"
            )

        t_next = min(t_arr, t_fin)
        if running and t_next > now:
            remaining[ra] = np.maximum(
                remaining[ra] - (t_next - now) * rate[ra], 0.0
            )
        now = t_next
        n_events += 1
        changed = set()

        if t_fin <= t_arr:                       # completion frees a slot
            remaining[j_fin] = 0.0
            finish[j_fin] = now
            pid = int(pool_of[j_fin])
            cluster.pool(pid).remove(jobs[j_fin])
            members[pid].remove(j_fin)
            running.remove(j_fin)
            done += 1
            changed.add(pid)
            # FIFO backlog re-offer (backfill-lite: any fitting job goes)
            still_queued = collections.deque()
            while backlog:
                q = backlog.popleft()
                pool = policy.select(jobs[q], cluster, now)
                if pool is not None and pool.is_open:
                    place(q, pool, now)
                    changed.add(pool.pool_id)
                else:
                    still_queued.append(q)
            backlog = still_queued
        else:                                    # arrival
            idx = order[i_arr]
            i_arr += 1
            pool = policy.select(jobs[idx], cluster, now)
            if pool is not None and pool.is_open:
                place(idx, pool, now)
                changed.add(pool.pool_id)
            else:
                backlog.append(idx)

        for pid in changed:
            refresh_rates(pid)

    assert not backlog and not running
    return SimResult(
        policy=policy.name,
        arrival=arrival, start=start, finish=finish, work=work,
        pool_of=pool_of, peak_occupancy=peak_occ, n_events=n_events,
    )


def run_policies(
    jobs: Sequence[TraceJob],
    spec: ClusterSpec,
    policy_names: Sequence[str] = ("fcfs", "random", "aware", "binpack"),
    *,
    seed: int = 0,
) -> Dict[str, SimResult]:
    """Run the same trace under several policies, each on a fresh cluster
    of the same topology."""
    out = {}
    for name in policy_names:
        cluster = Cluster.build(spec)
        out[name] = simulate(jobs, cluster, make_policy(name, seed=seed))
    return out
