"""Rack-scale cluster topology for the co-location simulator (paper §7.2).

The hierarchy is Cluster -> Rack -> Pool -> node slots. A `Pool` is one
shared-link contention domain: a disaggregated memory pool (host DRAM
behind PCIe here; CXL in the paper and in the rack-scale topologies of
arXiv:2211.02682) shared by `capacity` node slots. Every job resident in a
pool injects traffic on the pool link; the pool's instantaneous LoI seen by
a victim is the (saturation-capped) sum of everyone else's injected LoI,
exactly the `core.interference` model.

Racks only group pools — inter-rack traffic is out of scope (jobs never
span pools) — but keeping the level explicit lets policies prefer intra-rack
spreading and lets traces describe heterogeneous racks later.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, List, Optional

from repro.core.interference import background_lois


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    """Regular rack x pool x node topology (the common case)."""

    n_racks: int = 2
    pools_per_rack: int = 2
    nodes_per_pool: int = 4

    def __post_init__(self):
        for field in ("n_racks", "pools_per_rack", "nodes_per_pool"):
            if getattr(self, field) < 1:
                raise ValueError(f"{field} must be >= 1")

    @property
    def n_pools(self) -> int:
        return self.n_racks * self.pools_per_rack

    @property
    def total_slots(self) -> int:
        return self.n_pools * self.nodes_per_pool


@dataclasses.dataclass
class Pool:
    """One contention domain: `capacity` node slots behind a shared link.

    `jobs` holds the resident jobs — any object exposing `injected_loi`
    (the submission-time metric from `core.interference`).
    """

    pool_id: int
    rack_id: int
    capacity: int
    jobs: List = dataclasses.field(default_factory=list)

    @property
    def free_slots(self) -> int:
        return self.capacity - len(self.jobs)

    @property
    def is_open(self) -> bool:
        return self.free_slots > 0

    def total_injected_loi(self) -> float:
        return min(1.0, sum(j.injected_loi for j in self.jobs))

    def background_loi_for(self, job) -> float:
        """LoI the given (resident or candidate) job would see from the
        other residents."""
        return min(
            1.0,
            sum(j.injected_loi for j in self.jobs if j is not job),
        )

    def background_lois(self):
        """Vectorized per-resident background LoI (see
        `core.interference.background_lois`)."""
        return background_lois([j.injected_loi for j in self.jobs])

    def add(self, job) -> None:
        if not self.is_open:
            raise RuntimeError(f"pool {self.pool_id} is full")
        self.jobs.append(job)

    def remove(self, job) -> None:
        self.jobs.remove(job)


@dataclasses.dataclass
class Rack:
    rack_id: int
    pools: List[Pool]


@dataclasses.dataclass
class Cluster:
    spec: ClusterSpec
    racks: List[Rack]

    @classmethod
    def build(cls, spec: ClusterSpec) -> "Cluster":
        racks, pid = [], 0
        for r in range(spec.n_racks):
            pools = []
            for _ in range(spec.pools_per_rack):
                pools.append(Pool(pool_id=pid, rack_id=r,
                                  capacity=spec.nodes_per_pool))
                pid += 1
            racks.append(Rack(rack_id=r, pools=pools))
        return cls(spec=spec, racks=racks)

    @property
    def pools(self) -> List[Pool]:
        return [p for r in self.racks for p in r.pools]

    def pool(self, pool_id: int) -> Pool:
        p = self.pools[pool_id]
        assert p.pool_id == pool_id, "pool ids must be dense in build order"
        return p

    def open_pools(self) -> List[Pool]:
        return [p for p in self.pools if p.is_open]

    @property
    def total_capacity(self) -> int:
        return sum(p.capacity for p in self.pools)

    @property
    def occupancy(self) -> int:
        return sum(len(p.jobs) for p in self.pools)

    def iter_jobs(self) -> Iterator:
        for p in self.pools:
            yield from p.jobs

    def reset(self) -> None:
        """Evict every resident job (fresh run of the same topology)."""
        for p in self.pools:
            p.jobs.clear()


def build_cluster(n_racks: int = 2, pools_per_rack: int = 2,
                  nodes_per_pool: int = 4,
                  spec: Optional[ClusterSpec] = None) -> Cluster:
    """Convenience constructor used by examples/benchmarks/tests."""
    return Cluster.build(
        spec or ClusterSpec(n_racks, pools_per_rack, nodes_per_pool)
    )
