"""Job streams for the rack-scale simulator.

A `TraceJob` is a batch job the way the paper's §7.2 SLURM proposal sees
it: at submission time it carries its interference profile (sensitivity
curve + interference coefficient + injected LoI) computed by the
quantitative workflow — `core.quantify` for catalog models, or a synthetic
profile for trace studies. `work` is the job's isolated execution time
(steps x uncontended step time); the simulator stretches it by the
pool-contention slowdown while the job runs.

Two stream generators:

* `synthetic_stream` — fast (no per-job analysis, no model lowering):
  samples profiles across the paper's sensitivity quadrants
  (compute-bound HPL-likes through pool-bound Hypre-likes). 10k jobs
  build in milliseconds, so it backs the perf lane.
* `catalog_stream` — samples the model zoo in `repro.configs`, computing
  each (arch, shape) profile once via `core.quantify.profile_for` and
  reusing it across arrivals (the profile IS per-workload metadata, not
  per-job).

Arrivals are a Poisson process (exponential interarrival times); service
demand is lognormal-ish via a step-count range, matching the open-system
traces used in the CXL-pooling studies (arXiv:2211.02682).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.core import tiers as tr
from repro.core.interference import InterferenceProfile


@dataclasses.dataclass
class TraceJob:
    """One submitted job. Metrics are cached at submission (what a
    scheduler plugin would receive) so the hot simulation loop never calls
    back into the profile."""

    job_id: int
    name: str
    profile: InterferenceProfile
    arrival: float              # seconds since trace start
    work: float                 # isolated execution seconds
    # --- submission-time metrics (paper §7.2) ---
    injected_loi: float = dataclasses.field(init=False)
    ic: float = dataclasses.field(init=False)
    t_pool: float = dataclasses.field(init=False)
    t_local: float = dataclasses.field(init=False)
    t_compute: float = dataclasses.field(init=False)

    def __post_init__(self):
        self.injected_loi = self.profile.injected_loi()
        self.ic = self.profile.interference_coefficient()
        self.t_pool = self.profile.t_pool
        self.t_local = self.profile.t_local
        self.t_compute = self.profile.t_compute

    def sensitivity(self, loi: float) -> float:
        return self.profile.sensitivity(loi)


def synthetic_profile(pool_share: float, t_compute: float,
                      traffic: float = 1e9) -> InterferenceProfile:
    """A profile placed anywhere in the paper's Fig 10 sensitivity plane:
    `pool_share` of the per-step traffic crosses the pool link, the rest
    stays in HBM, against `t_compute` seconds of pure compute."""
    topo = tr.emulated(0.5, traffic)
    return InterferenceProfile(
        arch="synthetic", shape="trace",
        pool_traffic=traffic * pool_share,
        local_traffic=traffic * (1.0 - pool_share),
        t_compute=t_compute,
        topo=topo,
    )


def profile_with_injected_loi(r: float, pool_share: float = 0.5,
                              traffic: float = 1e9) -> InterferenceProfile:
    """A profile whose injected LoI is (approximately) `r` in (0, 1): the
    compute time is set to t_pool / r, so the job spends `r` of its step on
    the shared link. Its own sensitivity scales with the same `r` — a job
    that hammers the link is also exposed to it, the paper's injector-is-
    also-victim observation."""
    if not 0.0 < r <= 1.0:
        raise ValueError("injected LoI target must be in (0, 1]")
    topo = tr.emulated(0.5, traffic)
    t_pool = traffic * pool_share / topo.pool.bandwidth
    return InterferenceProfile(
        arch="synthetic", shape="trace",
        pool_traffic=traffic * pool_share,
        local_traffic=traffic * (1.0 - pool_share),
        t_compute=t_pool / r,
        topo=topo,
    )


def synthetic_stream(
    n_jobs: int,
    *,
    seed: int = 0,
    arrival_rate: float = 0.15,     # jobs/s; ~70% load on a 16-slot cluster
    runtime_median_s: float = 60.0,
    runtime_sigma: float = 0.6,
    loud_fraction: float = 0.3,
    loud_loi: tuple = (0.25, 0.6),
    quiet_loi: tuple = (0.01, 0.15),
) -> List[TraceJob]:
    """Mixed trace: ~`loud_fraction` link-heavy jobs (LBench-like
    injectors), the rest compute-bound — co-location policy only matters
    when some neighbours are loud and some are fragile. Isolated runtimes
    are lognormal around `runtime_median_s`; arrivals are Poisson.

    The default arrival rate offers ~70% utilization to the default
    2x2x4 cluster (16 slots / 60 s mean service), the regime where queues
    are short but pools really are shared.
    """
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / arrival_rate, size=n_jobs))
    jobs = []
    for i in range(n_jobs):
        if rng.uniform() < loud_fraction:
            r = rng.uniform(*loud_loi)              # Hypre/NekRS quadrant
        else:
            r = rng.uniform(*quiet_loi)             # HPL/XSBench quadrant
        pool_share = rng.uniform(0.3, 0.9)
        traffic = 10 ** rng.uniform(8.0, 9.5)
        prof = profile_with_injected_loi(r, pool_share, traffic)
        step0 = prof.step_time(0.0)
        target = runtime_median_s * np.exp(runtime_sigma * rng.normal())
        n_steps = max(1, int(round(target / step0)))
        jobs.append(TraceJob(
            job_id=i,
            name=f"job{i}",
            profile=prof,
            arrival=float(arrivals[i]),
            work=n_steps * step0,
        ))
    return jobs


def rescale_load(jobs: List[TraceJob], total_slots: int,
                 utilization: float = 0.7) -> List[TraceJob]:
    """Rescale arrival times in place so the offered load (total isolated
    work / available slot-seconds) is ~`utilization` — the regime where
    queues stay short but pools really are shared."""
    total_work = sum(j.work for j in jobs)
    span_needed = total_work / (total_slots * utilization)
    cur_span = max(j.arrival for j in jobs) or 1.0
    f = span_needed / cur_span
    for j in jobs:
        j.arrival *= f
    return jobs


def serving_stream(
    n_jobs: int,
    profile: InterferenceProfile,
    *,
    seed: int = 0,
    arrival_rate: float = 0.05,
    steps: tuple = (2_000, 20_000),
    name: Optional[str] = None,
) -> List[TraceJob]:
    """Serving instances as simulator jobs — the closed admission<->
    scheduler loop (ROADMAP): `profile` is the engine's MEASURED
    interference profile (`ServingEngine.measured_profile()`, per-step
    pool/local bytes from the pager's exact accounting), so a fleet of
    co-located serving jobs throttles each other in the simulator by the
    LoI each one actually injects — not a catalog prior. `steps` is the
    decode-step count range per instance (long-lived, decode-dominated
    services); isolated work prices each step at the profile's
    uncontended step time.
    """
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / arrival_rate, size=n_jobs))
    step0 = profile.step_time(0.0)
    name = name or f"serve/{profile.arch}"
    return [
        TraceJob(
            job_id=i,
            name=f"{name}#{i}",
            profile=profile,
            arrival=float(arrivals[i]),
            work=int(rng.integers(*steps)) * step0,
        )
        for i in range(n_jobs)
    ]


def fleet_request_stream(
    n: int,
    vocab: int,
    *,
    seed: int = 0,
    loud_fraction: float = 0.3,
    arrival_rate: float = 2.0,
    interactive_buckets: Sequence[int] = (16, 32),
    batch_bucket: int = 64,
    gen_interactive: tuple = (8, 16),
    gen_batch: tuple = (16, 32),
    cancel_fraction: float = 0.0,
    cancel_after: tuple = (0.5, 2.0),
):
    """Rack-sim job stream mapped onto fleet ROUTER traffic — the
    admission<->scheduler loop closed at fleet scale. The generator
    reuses `synthetic_stream`'s quadrant split, but instead of
    `TraceJob`s it emits serving `Request`s: a LOUD (link-heavy,
    Hypre-like) draw becomes a long-prompt priority-1 batch request
    (big KV footprint = the pool injector), a QUIET draw becomes a
    short-prompt priority-0 interactive request (the fragile
    bystander). `cancel_fraction` of requests carry a virtual-time
    `cancel_at` deadline (`arrival + U(*cancel_after)`) — deterministic
    cancellation load for the router's sweep path. Deterministic in
    `seed`; arrivals are the same Poisson process the rack-sim uses."""
    # imported lazily: serving pulls in jax, which synthetic users skip
    from repro.serving.queue import Request

    if not 0.0 <= cancel_fraction <= 1.0:
        raise ValueError("cancel_fraction must be in [0, 1]")
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / arrival_rate, size=n))
    out = []
    for i in range(n):
        loud = rng.uniform() < loud_fraction
        if loud:
            plen = batch_bucket
            gen = int(rng.integers(gen_batch[0], gen_batch[1] + 1))
            prio, tenant = 1, "batch"
        else:
            plen = int(rng.choice(list(interactive_buckets)))
            gen = int(rng.integers(gen_interactive[0],
                                   gen_interactive[1] + 1))
            prio, tenant = 0, "interactive"
        cancel_at = None
        if cancel_fraction and rng.uniform() < cancel_fraction:
            cancel_at = float(arrivals[i] + rng.uniform(*cancel_after))
        out.append(Request(
            request_id=i,
            tokens=rng.integers(0, vocab, size=plen).astype(np.int32),
            max_new_tokens=gen,
            arrival=float(arrivals[i]),
            priority=prio,
            tenant=tenant,
            cancel_at=cancel_at,
        ))
    return out


def catalog_stream(
    n_jobs: int,
    *,
    seed: int = 0,
    arrival_rate: float = 1.0,
    shapes: Sequence[str] = ("decode_32k",),
    archs: Optional[Sequence[str]] = None,
    steps: tuple = (120, 360),
    pool_fraction="auto",
    use_dryrun: bool = False,
    work_scale: float = 1.0,
) -> List[TraceJob]:
    """Stream sampled from the model catalog, uniformly over archs x
    `shapes`. Profiles are computed once per (arch, shape) cell by
    `core.quantify.profile_for` (cached) and shared by every job of that
    cell — submission cost stays O(|zoo|), not O(n_jobs).

    Shape mixing is what populates the paper's sensitivity quadrants from
    the catalog: decode/long cells are link-saturating injectors, while
    train/prefill cells are compute-bound bystanders. `pool_fraction`
    defaults to the pool-by-necessity adoption scenario; pass a float
    (e.g. 0.5) for the paper-style emulated R_cap stress. `work_scale`
    rescales isolated runtimes so short trace studies do not need millions
    of decode steps to reach steady state.
    """
    # imported lazily: quantify pulls in jax, which synthetic users skip
    from repro import configs
    from repro.core.quantify import profile_for

    rng = np.random.default_rng(seed)
    archs = list(archs) if archs is not None else configs.list_archs()
    cells = [(a, s) for a in archs for s in shapes]
    profiles = {
        cell: profile_for(cell[0], cell[1], pool_fraction=pool_fraction,
                          use_dryrun=use_dryrun)
        for cell in cells
    }
    arrivals = np.cumsum(rng.exponential(1.0 / arrival_rate, size=n_jobs))
    jobs = []
    for i in range(n_jobs):
        arch, shape = cells[int(rng.integers(len(cells)))]
        prof = profiles[(arch, shape)]
        n_steps = int(rng.integers(*steps))
        jobs.append(TraceJob(
            job_id=i,
            name=f"{arch}:{shape}#{i}",
            profile=prof,
            arrival=float(arrivals[i]),
            work=work_scale * n_steps * prof.step_time(0.0),
        ))
    return jobs
