"""Interference-aware job scheduling (paper §7.2).

Jobs carry the two level-3 metrics from core.interference — sensitivity
profile and interference coefficient (supplied "at job submission", as the
paper proposes for SLURM). Pools (one per host group) are the contention
domains. The interference-aware scheduler avoids co-locating high-IC jobs
with high-sensitivity jobs on the same pool; the random scheduler is the
paper's baseline.

`simulate_colocation` reproduces the paper's Fig 13 experiment: each
workload runs many times against a background whose LoI changes randomly
every interval; the aware scheduler caps the background range (0-20% vs
0-50%) by keeping loud neighbours away.

This module is the single-pool toy. The rack-scale, event-driven version —
where the background LoI is derived from actual co-residents instead of
assumed — lives in `repro.sched.simulator` (+ `cluster`, `policies`,
`workload`).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import numpy as np

from repro.core.interference import InterferenceProfile


@dataclasses.dataclass
class Job:
    name: str
    profile: InterferenceProfile
    steps: int = 100

    @property
    def ic(self) -> float:
        return self.profile.interference_coefficient()

    @property
    def injected_loi(self) -> float:
        return self.profile.injected_loi()

    def sensitivity(self, loi: float) -> float:
        return self.profile.sensitivity(loi)


@dataclasses.dataclass
class Pool:
    pool_id: int
    capacity: int                     # jobs per pool (nodes per rack)
    jobs: list = dataclasses.field(default_factory=list)

    def background_loi_for(self, job: Job) -> float:
        return min(1.0, sum(j.injected_loi for j in self.jobs if j is not job))


class RandomScheduler:
    """Paper baseline: first-fit in arrival order (no interference info)."""

    def __init__(self, n_pools: int, capacity: int, seed: int = 0):
        self.pools = [Pool(i, capacity) for i in range(n_pools)]
        self.rng = np.random.default_rng(seed)

    def place(self, job: Job) -> Optional[Pool]:
        open_pools = [p for p in self.pools if len(p.jobs) < p.capacity]
        if not open_pools:
            return None
        p = open_pools[self.rng.integers(len(open_pools))]
        p.jobs.append(job)
        return p


class InterferenceAwareScheduler:
    """Minimize predicted total slowdown: place each job on the pool where
    (its own degradation) + (degradation it inflicts on residents) is
    smallest. Uses only submission-time metrics (IC + sensitivity), per the
    paper's proposal."""

    def __init__(self, n_pools: int, capacity: int):
        self.pools = [Pool(i, capacity) for i in range(n_pools)]

    def place(self, job: Job) -> Optional[Pool]:
        from repro.sched.policies import marginal_colocation_cost

        open_pools = [p for p in self.pools if len(p.jobs) < p.capacity]
        if not open_pools:
            return None
        best = min(open_pools,
                   key=lambda p: marginal_colocation_cost(p, job))
        best.jobs.append(job)
        return best

    def place_all(self, jobs) -> bool:
        """Batch mode: place loudest jobs first so they spread across pools
        before the sensitive ones choose their neighbours (greedy-online is
        myopic under arbitrary arrival order)."""
        ordered = sorted(jobs, key=lambda j: -j.injected_loi)
        return all(self.place(j) is not None for j in ordered)


def simulate_colocation(
    job: Job,
    n_runs: int = 100,
    *,
    loi_range: tuple[float, float] = (0.0, 0.5),
    interval_steps: int = 60,
    seed: int = 0,
) -> np.ndarray:
    """Paper Fig 13: run `job` n_runs times; background LoI resampled
    uniformly from loi_range every `interval_steps` steps. Returns total
    runtimes (seconds)."""
    rng = np.random.default_rng(seed)
    base = job.profile.step_time(0.0)
    runtimes = np.empty(n_runs)
    for r in range(n_runs):
        t = 0.0
        steps_left = job.steps
        while steps_left > 0:
            chunk = min(interval_steps, steps_left)
            loi = rng.uniform(*loi_range)
            t += chunk * base / max(job.sensitivity(loi), 1e-6)
            steps_left -= chunk
        runtimes[r] = t
    return runtimes


def five_number_summary(x: np.ndarray) -> dict:
    return {
        "min": float(np.min(x)),
        "p25": float(np.percentile(x, 25)),
        "median": float(np.median(x)),
        "p75": float(np.percentile(x, 75)),
        "max": float(np.max(x)),
        "mean": float(np.mean(x)),
    }
