"""While-loop-aware HLO cost analysis.

XLA's `compiled.cost_analysis()` counts a while-loop body ONCE, which makes
it useless for scan-over-layers programs (verified in this container: a
scan of 8 matmuls reports the flops of 1). This module re-derives the three
roofline inputs from the optimized per-device HLO text:

  * flops            — dot ops: 2 * prod(out_dims) * prod(contracted dims);
                       elementwise/fusion internals approximated by output
                       element counts (second-order, dominated by dots)
  * hbm_bytes        — fusion-boundary traffic: every top-level op reads its
                       operands and writes its outputs once; fusion internals
                       are free (that is what fusion means)
  * collective wire bytes — per collective op, ring-model bytes on the wire
                       per device (all-gather: (g-1)/g * out, all-reduce:
                       2(g-1)/g * in, reduce-scatter: (g-1)/g * in,
                       all-to-all: (g-1)/g * in, permute: in)

Each while op's body cost is multiplied by its trip count, parsed from the
`constant(N)` in its condition computation (jax lax.scan lowers to exactly
this form). Nested whiles compose. If a trip count cannot be parsed, 1 is
used and the op is recorded in `warnings`.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_NAME_RE = re.compile(r"%([\w\.\-]+)")


def _shape_bytes_elems(type_str: str):
    """Sum bytes and element count over all shapes in a type string
    (handles tuples)."""
    total_b = 0
    total_e = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in DTYPE_BYTES:
            continue
        elems = 1
        if dims:
            for d in dims.split(","):
                elems *= int(d)
        total_e += elems
        total_b += elems * DTYPE_BYTES[dt]
    return total_b, total_e


@dataclasses.dataclass
class OpInfo:
    name: str
    opcode: str
    out_type: str
    operands: list
    attrs: str
    args_text: str = ""
    out_bytes: int = 0
    out_elems: int = 0
    scope: str = ""          # jax op_name path from HLO metadata


# Ops lowered from these source scopes correspond to the Pallas flash kernels
# on the TPU target: their fp32 score/ds tiles live in VMEM, never HBM. The
# fused-HBM model therefore counts only their bf16 tile reads/writes (q/k/v/o
# blocks), which is exactly the Pallas kernel's HBM traffic.
VMEM_SCOPE_RE = re.compile(r"flash_vmem|ssd_vmem|decode_vmem|lbench_vmem")


@dataclasses.dataclass
class Computation:
    name: str
    ops: list


def _parse_computations(hlo: str) -> dict:
    comps = {}
    cur = None
    for raw in hlo.splitlines():
        line = raw.strip()
        # computation header: `%name (params...) -> type {` or `ENTRY %name ...`
        m = re.match(r"(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$",
                     line)
        if m and "=" not in line.split("(")[0]:
            cur = Computation(m.group(1), [])
            comps[cur.name] = cur
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None or "=" not in line:
            continue
        sm = re.search(r'op_name="([^"]*)"', line)
        scope = sm.group(1) if sm else ""
        # strip metadata (contains parens/brackets that confuse parsing)
        line_nom = re.sub(r",?\s*metadata=\{.*?\}", "", line)
        line_nom = re.sub(r",?\s*backend_config=.*$", "", line_nom)
        m = re.match(
            r"(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$",
            line_nom,
        )
        if not m:
            continue
        name, out_type, opcode, rest = m.groups()
        depth = 1
        args = []
        buf = ""
        i = 0
        while i < len(rest) and depth > 0:
            ch = rest[i]
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    args.append(buf)
                    break
            elif ch == "," and depth == 1:
                args.append(buf)
                buf = ""
                i += 1
                continue
            buf += ch
            i += 1
        attrs = rest[i + 1:] if i + 1 < len(rest) else ""
        operands = []
        for a in args:
            nm = _NAME_RE.search(a)
            if nm:
                operands.append(nm.group(1))
        ob, oe = _shape_bytes_elems(out_type)
        cur.ops.append(
            OpInfo(name, opcode, out_type, operands, attrs,
                   ",".join(args), ob, oe, scope)
        )
    return comps


def _dot_flops(op: OpInfo, shape_of: dict) -> float:
    # contracted dim sizes from lhs shape + lhs_contracting_dims
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.attrs)
    if not m or not op.operands:
        return 2.0 * op.out_elems  # fallback
    cdims = [int(x) for x in m.group(1).split(",") if x]
    lhs_type = shape_of.get(op.operands[0], "")
    sm = _SHAPE_RE.search(lhs_type)
    if not sm:
        return 2.0 * op.out_elems
    dims = [int(x) for x in sm.group(2).split(",") if x]
    k = 1
    for c in cdims:
        if c < len(dims):
            k *= dims[c]
    return 2.0 * op.out_elems * k


def _group_size(op: OpInfo, default: int) -> int:
    m = re.search(r"replica_groups=\{\{([0-9,]+)\}", op.attrs)
    if m:
        return len(m.group(1).split(","))
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", op.attrs)
    if m:  # iota format [groups,group_size]
        return int(m.group(2))
    return default


def _cond_trip_count(cond: Computation) -> int | None:
    """jax scans compare the loop counter with a s32[] constant."""
    best = None
    for op in cond.ops:
        if op.opcode == "constant" and op.out_type.startswith("s32"):
            m = re.match(r"\s*(\d+)\s*$", op.args_text or "")
            if m:
                v = int(m.group(1))
                best = v if best is None else max(best, v)
    return best


def _fusion_bytes(op: OpInfo, callee, shape_of: dict) -> float:
    """HBM traffic of a fusion: operands + outputs, EXCEPT
    - an operand whose in-fusion users are all dynamic-slice ops counts as
      the sum of the slice outputs (scan bodies slice the current layer out
      of the stacked params — the fusion reads the slice, not the stack);
    - a fusion whose root is dynamic-update-slice writes the update region,
      not the whole carried buffer (in-place accumulation).
    """
    if callee is None:
        total = sum(
            _shape_bytes_elems(shape_of.get(o, ""))[0] for o in op.operands
        )
        return total + op.out_bytes

    params = {}
    for cop in callee.ops:
        if cop.opcode == "parameter":
            m = re.match(r"\s*(\d+)", cop.args_text or "")
            if m:
                params[int(m.group(1))] = cop.name
    users: dict = defaultdict(list)
    for cop in callee.ops:
        for o in cop.operands:
            users[o].append(cop)

    total = 0.0
    for i, o in enumerate(op.operands):
        full = _shape_bytes_elems(shape_of.get(o, ""))[0]
        pname = params.get(i)
        if pname is not None:
            u = users.get(pname, [])
            if u and all(c.opcode in ("dynamic-slice", "slice") for c in u):
                total += sum(c.out_bytes for c in u)
                continue
            if u and all(
                c.opcode == "dynamic-update-slice" and c.operands
                and c.operands[0] == pname for c in u
            ):
                # buffer updated in place: read side ~ update region
                total += sum(
                    _shape_bytes_elems(shape_of.get(c.operands[1], ""))[0]
                    for c in u if len(c.operands) > 1
                )
                continue
        total += full

    root = callee.ops[-1] if callee.ops else None
    out_b = op.out_bytes
    if root is not None and root.opcode == "dynamic-update-slice" and \
            len(root.operands) > 1:
        out_b = _shape_bytes_elems(shape_of.get(root.operands[1], ""))[0]
    return total + out_b


@dataclasses.dataclass
class HloCostModel:
    flops: float
    hbm_bytes: float           # TPU-fusion model (primary; see below)
    hbm_bytes_raw: float       # CPU-fusion-boundary model (upper bound)
    wire_bytes: float
    collective_by_kind: dict
    warnings: list

    def scaled(self, f: float) -> "HloCostModel":
        return HloCostModel(
            self.flops * f, self.hbm_bytes * f, self.hbm_bytes_raw * f,
            self.wire_bytes * f,
            {k: v * f for k, v in self.collective_by_kind.items()},
            list(self.warnings),
        )


def analyze_hlo(hlo: str, default_group: int = 1) -> HloCostModel:
    comps = _parse_computations(hlo)

    # global shape table (op name -> out type string)
    shape_of = {}
    for c in comps.values():
        for op in c.ops:
            shape_of[op.name] = op.out_type

    warnings: list = []
    memo: dict = {}

    # Some XLA passes (e.g. the "wide" while-loop transform) clone regions
    # without metadata; ops with an empty scope inherit their computation's
    # majority scope so VMEM-kernel regions stay recognized.
    comp_vmem: dict = {}
    for cname, c in comps.items():
        scoped = [op.scope for op in c.ops if op.scope]
        hits = sum(1 for s in scoped if VMEM_SCOPE_RE.search(s))
        comp_vmem[cname] = bool(scoped) and hits * 2 > len(scoped)

    def op_in_vmem_scope(op, comp_name):
        if op.scope:
            return bool(VMEM_SCOPE_RE.search(op.scope))
        return comp_vmem.get(comp_name, False)

    def in_bytes(op):
        return sum(
            _shape_bytes_elems(shape_of.get(o, ""))[0] for o in op.operands
        )

    def cost_of(comp_name: str) -> tuple:
        """Returns (flops, hbm_raw, hbm_fused, wire, coll_dict)."""
        if comp_name in memo:
            return memo[comp_name]
        comp = comps.get(comp_name)
        if comp is None:
            return (0.0, 0.0, 0.0, 0.0, {})
        memo[comp_name] = (0.0, 0.0, 0.0, 0.0, {})  # cycle guard
        flops = 0.0
        raw = 0.0
        fused = 0.0
        wire = 0.0
        coll: dict = defaultdict(float)
        for op in comp.ops:
            oc = op.opcode
            if oc in ("parameter", "constant", "get-tuple-element", "tuple",
                      "bitcast", "copy", "copy-start", "copy-done",
                      "after-all", "partition-id", "replica-id", "iota"):
                continue
            if oc == "fusion":
                m = re.search(r"calls=%?([\w\.\-]+)", op.attrs)
                callee = comps.get(m.group(1)) if m else None
                if m:
                    f2, _r, fu2, w2, c2 = cost_of(m.group(1))
                    flops += f2
                    fused += fu2
                    wire += w2
                    for k, v in c2.items():
                        coll[k] += v
                raw += _fusion_bytes(op, callee, shape_of)
                continue
            if oc == "while":
                mb = re.search(r"body=%?([\w\.\-]+)", op.attrs)
                mc = re.search(r"condition=%?([\w\.\-]+)", op.attrs)
                trip = None
                if mc and mc.group(1) in comps:
                    trip = _cond_trip_count(comps[mc.group(1)])
                if trip is None:
                    trip = 1
                    warnings.append(f"while {op.name}: trip count unknown")
                if mb:
                    f2, r2, fu2, w2, c2 = cost_of(mb.group(1))
                    flops += trip * f2
                    raw += trip * r2
                    fused += trip * fu2
                    wire += trip * w2
                    for k, v in c2.items():
                        coll[k] += trip * v
                continue
            if oc in ("call", "custom-call"):
                m = re.search(
                    r"(?:to_apply|called_computations)=\{?%?([\w\.\-]+)",
                    op.attrs,
                )
                if m:
                    f2, r2, fu2, w2, c2 = cost_of(m.group(1))
                    flops += f2
                    raw += r2
                    fused += fu2
                    wire += w2
                    for k, v in c2.items():
                        coll[k] += v
                b = in_bytes(op) + op.out_bytes
                raw += b
                continue
            if oc == "conditional":
                for m in re.finditer(
                    r"(?:true_computation|false_computation|branch_computations=\{)%?([\w\.\-]+)",
                    op.attrs,
                ):
                    f2, r2, fu2, w2, c2 = cost_of(m.group(1))
                    flops += f2
                    raw += r2
                    fused += fu2
                    wire += w2
                    for k, v in c2.items():
                        coll[k] += v
                continue

            # ---- leaf ops ----
            in_vmem_scope = op_in_vmem_scope(op, comp_name)
            if oc in ("dynamic-slice", "slice", "gather"):
                raw += 2 * op.out_bytes
                if not in_vmem_scope:   # in-kernel tile reads counted at dots
                    fused += 2 * op.out_bytes
                continue
            if oc == "dynamic-update-slice":
                upd = (
                    _shape_bytes_elems(shape_of.get(op.operands[1], ""))[0]
                    if len(op.operands) > 1 else op.out_bytes
                )
                raw += 2 * upd
                # in-kernel accumulator flushes stay in VMEM; the final
                # output write is counted at the consumer's dot operand
                if not in_vmem_scope:
                    fused += 2 * upd
                continue
            if oc == "scatter":
                upd = (
                    _shape_bytes_elems(shape_of.get(op.operands[-1], ""))[0]
                    if op.operands else op.out_bytes
                )
                raw += 2 * upd
                fused += 2 * upd
                continue

            is_coll = None
            for ck in COLLECTIVES:
                if oc.startswith(ck):
                    is_coll = ck
                    break
            if is_coll:
                b_in = in_bytes(op)
                g = _group_size(op, default_group)
                if g <= 1:
                    w = 0.0
                elif is_coll == "all-gather":
                    w = op.out_bytes * (g - 1) / g
                elif is_coll == "all-reduce":
                    w = 2.0 * b_in * (g - 1) / g
                elif is_coll == "reduce-scatter":
                    w = b_in * (g - 1) / g
                elif is_coll == "all-to-all":
                    w = b_in * (g - 1) / g
                else:  # collective-permute
                    w = b_in
                wire += w
                coll[is_coll] += w
                raw += b_in + op.out_bytes
                fused += b_in + op.out_bytes
                continue

            b_in = in_bytes(op)
            if oc == "dot":
                flops += _dot_flops(op, shape_of)
                raw += b_in + op.out_bytes
                if in_vmem_scope:
                    # Pallas-kernel region: only 2-byte tile traffic is HBM;
                    # fp32 score/ds tiles live in VMEM
                    small = 0
                    for o in op.operands:
                        t = shape_of.get(o, "")
                        if t.startswith(("bf16", "f16", "s8", "u8")):
                            small += _shape_bytes_elems(t)[0]
                    if op.out_type.startswith(("bf16", "f16")):
                        small += op.out_bytes
                    fused += small
                else:
                    fused += b_in + op.out_bytes
                continue
            if oc == "convolution":
                flops += 2.0 * op.out_elems
                raw += b_in + op.out_bytes
                fused += b_in + op.out_bytes
                continue
            if oc in ("reduce", "reduce-window", "sort"):
                flops += 1.0 * op.out_elems
                raw += b_in + op.out_bytes
                if not in_vmem_scope:
                    fused += op.out_bytes  # input side fuses with producer
                continue
            # pure elementwise / shape ops: free under TPU fusion model
            if oc in ("exponential", "log", "rsqrt", "sqrt", "tanh",
                      "power", "divide", "logistic", "exponential-minus-one"):
                flops += 4.0 * op.out_elems
            elif oc in ("add", "subtract", "multiply", "negate", "abs",
                        "maximum", "minimum", "compare", "select",
                        "clamp", "and", "or", "xor"):
                flops += 1.0 * op.out_elems
            raw += b_in + op.out_bytes
        result = (flops, raw, fused, wire, dict(coll))
        memo[comp_name] = result
        return result

    # entry computation = the one not called by anyone
    called = set()
    for c in comps.values():
        for op in c.ops:
            for m in re.finditer(
                r"(?:calls|body|condition|to_apply|true_computation|false_computation)=%?([\w\.\-]+)",
                op.attrs,
            ):
                called.add(m.group(1))
            m = re.search(r"branch_computations=\{([^}]*)\}", op.attrs)
            if m:
                for nm in _NAME_RE.finditer(m.group(1)):
                    called.add(nm.group(1))
    entries = [c for c in comps if c not in called]
    entry = None
    for c in entries:
        if entry is None or len(comps[c].ops) > len(comps[entry].ops):
            entry = c
    if entry is None:
        return HloCostModel(0, 0, 0, 0, {}, ["no entry computation found"])
    flops, raw, fused, wire, coll = cost_of(entry)
    return HloCostModel(flops, fused, raw, wire, coll, warnings)
