"""Hotspot attribution: aggregate dot-flops / HBM bytes / collective wire
bytes by source scope (jax op_name path), with while-loop trip multipliers.
The dry-run profiler's equivalent of a wall-clock profile."""

from __future__ import annotations

import re
from collections import defaultdict

from repro.profiler.hlo import (
    COLLECTIVES,
    _cond_trip_count,
    _dot_flops,
    _group_size,
    _parse_computations,
    _shape_bytes_elems,
)


def _scope_key(scope: str, depth: int) -> str:
    parts = [p for p in scope.split("/") if p and not p.startswith("jit(")]
    # drop while/body noise, keep semantic names
    parts = [p for p in parts if p not in
             ("while", "body", "cond", "closed_call", "jvp()", )]
    return "/".join(parts[:depth]) if parts else "(unscoped)"


def hotspots(hlo: str, depth: int = 3, default_group: int = 1):
    comps = _parse_computations(hlo)
    shape_of = {}
    for c in comps.values():
        for op in c.ops:
            shape_of[op.name] = op.out_type

    # computation -> trip multiplier (product over enclosing whiles)
    mult = defaultdict(lambda: 1.0)
    # build call graph with multipliers, starting from entry
    called = set()
    for c in comps.values():
        for op in c.ops:
            for m in re.finditer(
                r"(?:calls|body|condition|to_apply)=%?([\w\.\-]+)", op.attrs
            ):
                called.add(m.group(1))
    entries = [c for c in comps if c not in called]
    entry = max(entries, key=lambda c: len(comps[c].ops), default=None)
    if entry is None:
        return {}

    seen = set()

    def walk(name, factor):
        if name not in comps or (name, factor) in seen:
            return
        seen.add((name, factor))
        mult[name] = max(mult[name], factor) if name in mult else factor
        for op in comps[name].ops:
            if op.opcode == "while":
                mb = re.search(r"body=%?([\w\.\-]+)", op.attrs)
                mc = re.search(r"condition=%?([\w\.\-]+)", op.attrs)
                trip = 1
                if mc and mc.group(1) in comps:
                    trip = _cond_trip_count(comps[mc.group(1)]) or 1
                if mb:
                    walk(mb.group(1), factor * trip)
            else:
                for m in re.finditer(
                    r"(?:calls|to_apply|true_computation|false_computation)=%?([\w\.\-]+)",
                    op.attrs,
                ):
                    walk(m.group(1), factor)

    mult[entry] = 1.0
    walk(entry, 1.0)

    agg = defaultdict(lambda: {"flops": 0.0, "wire": 0.0, "count": 0})
    for cname, c in comps.items():
        f = mult.get(cname, 1.0)
        for op in c.ops:
            key = _scope_key(op.scope, depth)
            if op.opcode == "dot":
                agg[key]["flops"] += f * _dot_flops(op, shape_of)
                agg[key]["count"] += 1
            for ck in COLLECTIVES:
                if op.opcode.startswith(ck):
                    in_b = sum(
                        _shape_bytes_elems(shape_of.get(o, ""))[0]
                        for o in op.operands
                    )
                    g = _group_size(op, default_group)
                    w = in_b * (g - 1) / max(g, 1)
                    if ck == "all-reduce":
                        w *= 2
                    elif ck == "all-gather":
                        w = op.out_bytes * (g - 1) / max(g, 1)
                    agg[key]["wire"] += f * w
                    agg[key]["count"] += 1
    return dict(agg)


def print_hotspots(hlo: str, depth: int = 4, top: int = 15):
    agg = hotspots(hlo, depth)
    rows = sorted(agg.items(), key=lambda kv: -kv[1]["flops"])
    print(f"{'scope':70s} {'Tflops':>10s} {'wireGB':>8s}")
    for k, v in rows[:top]:
        print(f"{k[:70]:70s} {v['flops'] / 1e12:10.2f} "
              f"{v['wire'] / 1e9:8.2f}")
    rows = sorted(agg.items(), key=lambda kv: -kv[1]["wire"])
    print("--- by wire ---")
    for k, v in rows[:top // 2]:
        print(f"{k[:70]:70s} {v['flops'] / 1e12:10.2f} "
              f"{v['wire'] / 1e9:8.2f}")
