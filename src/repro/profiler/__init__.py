from repro.profiler.hlo import HloCostModel, analyze_hlo  # noqa
