"""End-to-end driver: train a ~100M-param LM for a few hundred steps on the
synthetic pipeline, with checkpoint/restart + straggler watchdog.

    PYTHONPATH=src:. python examples/train_lm.py --steps 300

This is the full production code path (launch.train) on a CPU-sized config;
on a pod, drop --reduced-dims and point --arch at any registry entry.
"""

import argparse
import dataclasses
import sys

sys.path.insert(0, "src")

import jax  # noqa: E402

from repro import configs  # noqa: E402
from repro.checkpoint import CheckpointManager  # noqa: E402
from repro.common.config import TrainConfig  # noqa: E402
from repro.data import PrefetchPipeline  # noqa: E402
from repro.data.synthetic import make_batch_for  # noqa: E402
from repro.launch.mesh import ctx_for_mesh, make_smoke_mesh  # noqa: E402
from repro.runtime import sharding as shd  # noqa: E402
from repro.runtime import train as train_rt  # noqa: E402
from repro.runtime.fault import StragglerWatchdog  # noqa: E402


def hundred_m_config():
    """~100M-param llama-style config (d=768, 12L, 32k vocab)."""
    base = configs.get("smollm_360m")
    return dataclasses.replace(
        base, name="lm-100m", num_layers=12, d_model=768, num_heads=12,
        num_kv_heads=4, head_dim=64, d_ff=2048, vocab_size=32768,
        tie_embeddings=True,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_example_ckpt")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny dims for CI (seconds, not minutes)")
    args = ap.parse_args()

    cfg = hundred_m_config()
    if args.smoke:
        cfg = dataclasses.replace(cfg, num_layers=2, d_model=128,
                                  num_heads=4, num_kv_heads=2, head_dim=32,
                                  d_ff=256, vocab_size=512)
    print(f"model: {cfg.name}  params={cfg.param_count() / 1e6:.1f}M")

    mesh = make_smoke_mesh()
    ctx = ctx_for_mesh(mesh, fsdp=False)
    rules = shd.ShardingRules.for_training(None, None)
    tcfg = TrainConfig(learning_rate=args.lr, total_steps=args.steps,
                       warmup_steps=args.steps // 10)
    example = make_batch_for(cfg, args.seq, args.batch, 0)
    bundle = train_rt.make_bundle(cfg, ctx, tcfg, rules, mesh, example)
    state, _ = train_rt.init_train_state(cfg, jax.random.PRNGKey(0))

    ckpt = CheckpointManager(args.ckpt_dir)
    pipe = PrefetchPipeline(
        lambda s: make_batch_for(cfg, args.seq, args.batch, s)
    )
    dog = StragglerWatchdog()
    losses = []
    try:
        for step in range(args.steps):
            _, batch = pipe.get()
            dog.start_step()
            state, metrics = bundle.step_fn(state, batch)
            dog.end_step(step)
            losses.append(float(metrics["loss"]))
            if step % 25 == 0 or step == args.steps - 1:
                print(f"step {step:4d} loss {losses[-1]:.4f} "
                      f"acc {float(metrics['accuracy']):.3f}")
            if (step + 1) % 100 == 0:
                ckpt.save(step + 1, state)
    finally:
        pipe.close()
        ckpt.wait()
    print(f"loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"({len(dog.flagged)} straggler events)")
    assert losses[-1] < losses[0], "did not learn"


if __name__ == "__main__":
    main()
