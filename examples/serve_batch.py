"""Serving example: the continuous-batching engine on the reduced
paligemma VLM (frontend-stub path, one-shot burst) and on smollm under the
bursty arrival scenario with tier-aware KV paging.

    PYTHONPATH=src:. python examples/serve_batch.py
"""

import sys

sys.path.insert(0, "src")

from repro.launch import serve  # noqa: E402


def main():
    serve.main([
        "--arch", "paligemma-3b", "--reduced",
        "--batch", "4", "--prompt-len", "24", "--gen", "12",
    ])
    serve.main([
        "--arch", "smollm-360m", "--reduced",
        "--scenario", "bursty", "--requests", "12", "--slots", "4",
    ])


if __name__ == "__main__":
    main()
