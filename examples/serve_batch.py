"""Serving example: batched prefill + autoregressive decode with KV caches
(greedy), on the reduced paligemma VLM (exercises the frontend-stub path).

    PYTHONPATH=src:. python examples/serve_batch.py
"""

import sys

sys.path.insert(0, "src")

from repro.launch import serve  # noqa: E402


def main():
    serve.main([
        "--arch", "paligemma-3b", "--reduced",
        "--batch", "4", "--prompt-len", "24", "--gen", "12",
    ])
    serve.main([
        "--arch", "mamba2-780m", "--reduced",
        "--batch", "2", "--prompt-len", "32", "--gen", "8",
    ])


if __name__ == "__main__":
    main()
