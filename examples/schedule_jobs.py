"""Interference-aware scheduling example (paper case study 2), rack scale:
stream the arch zoo as decode jobs into a 2-rack x 2-pool x 3-node cluster,
compare FCFS / random / interference-aware / corridor bin-packing under the
event-driven simulator, then reproduce the Fig 13 Monte-Carlo for the most
sensitive workload.

    PYTHONPATH=src:. python examples/schedule_jobs.py
"""

import sys

sys.path.insert(0, "src")

import numpy as np  # noqa: E402

from repro import configs  # noqa: E402
from repro.core.quantify import profile_for  # noqa: E402
from repro.sched import (  # noqa: E402
    ClusterSpec,
    Job,
    catalog_stream,
    rescale_load,
    run_policies,
    simulate_colocation,
)
from repro.sched.scheduler import five_number_summary  # noqa: E402

# Paper-style emulated R_cap stress: every workload keeps half its working
# set on the pool — with "auto" (pool-by-necessity) only the 1T MoE spills
# and the co-location question disappears.
POOL_FRACTION = 0.5


SHAPES_MIX = ("train_4k", "prefill_32k", "decode_32k")


def main():
    archs = configs.list_archs()
    profiles = {
        (a, s): profile_for(a, s, pool_fraction=POOL_FRACTION)
        for a in archs for s in SHAPES_MIX
    }

    print("workload (loudest and quietest cells)   IC     inj_LoI  sens@50%")
    ranked = sorted(profiles, key=lambda c: -profiles[c].injected_loi())
    for cell in ranked[:4] + ranked[-4:]:
        p = profiles[cell]
        label = f"{cell[0]}:{cell[1]}"
        print(f"{label:38s} {p.interference_coefficient():6.3f} "
              f"{p.injected_loi():8.3f} {p.sensitivity(0.5):8.3f}")

    # --- rack-scale trace: mixed-shape catalog jobs over 4 pools --------
    spec = ClusterSpec(n_racks=2, pools_per_rack=2, nodes_per_pool=3)
    jobs = catalog_stream(200, seed=0, shapes=SHAPES_MIX,
                          pool_fraction=POOL_FRACTION, work_scale=0.02)
    rescale_load(jobs, spec.total_slots, utilization=0.7)
    results = run_policies(jobs, spec, seed=0)
    print(f"\n{len(jobs)} catalog jobs over {spec.n_pools} pools "
          f"({spec.total_slots} slots):")
    print("policy    mean_slow  var_slow  p95_slow  mean_wait  makespan")
    for name, r in results.items():
        s = r.summary()
        print(f"{name:8s} {s['mean_slowdown']:9.3f} {s['var_slowdown']:9.4f} "
              f"{s['p95_slowdown']:9.3f} {s['mean_wait_s']:9.1f}s "
              f"{s['makespan_s']:8.0f}s")

    # --- paper Fig 13 Monte-Carlo for the most sensitive workload -------
    sensitive = max(profiles, key=lambda c: 1 - profiles[c].sensitivity(0.5))
    job = Job(f"{sensitive[0]}:{sensitive[1]}", profiles[sensitive],
              steps=240)
    base = simulate_colocation(job, 100, loi_range=(0, 0.5), seed=1)
    opt = simulate_colocation(job, 100, loi_range=(0, 0.2), seed=1)
    sb, so = five_number_summary(base), five_number_summary(opt)
    print(f"\nFig13 for most-sensitive workload ({job.name}):")
    print(f"  random: median={sb['median']:.3e}s p75={sb['p75']:.3e}s")
    print(f"  aware : median={so['median']:.3e}s p75={so['p75']:.3e}s "
          f"({100 * (sb['p75'] - so['p75']) / sb['p75']:.1f}% p75 cut)")


if __name__ == "__main__":
    main()
