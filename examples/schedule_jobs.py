"""Interference-aware scheduling example (paper case study 2): submit the
whole arch zoo as decode jobs to 4 rack pools, compare the random baseline
with the interference-aware scheduler, then Monte-Carlo the co-location.

    PYTHONPATH=src:. python examples/schedule_jobs.py
"""

import sys

sys.path.insert(0, "src")

import numpy as np  # noqa: E402

from repro import configs  # noqa: E402
from repro.core.quantify import analyze  # noqa: E402
from repro.sched import (  # noqa: E402
    InterferenceAwareScheduler,
    Job,
    RandomScheduler,
    simulate_colocation,
)
from repro.sched.scheduler import five_number_summary  # noqa: E402


def main():
    jobs = []
    for arch in configs.list_archs():
        a = analyze(arch, "decode_32k", policy="hotness",
                    pool_fraction="auto", use_dryrun=False)
        jobs.append(Job(arch, a.profile, steps=240))
    jobs.sort(key=lambda j: -j.ic)

    print("job            IC     injected_LoI  sens@50%")
    for j in jobs:
        print(f"{j.name:22s} {j.ic:6.3f} {j.injected_loi:10.3f} "
              f"{j.sensitivity(0.5):8.3f}")

    def placed_slowdown(pools):
        tot = 0.0
        for p in pools:
            for j in p.jobs:
                tot += 1.0 / max(j.sensitivity(p.background_loi_for(j)),
                                 1e-6)
        return tot / len(jobs)

    rand = RandomScheduler(4, 3, seed=0)
    aware = InterferenceAwareScheduler(4, 3)
    for j in jobs:
        rand.place(j)
        aware.place(j)
    print(f"\nmean predicted slowdown: random={placed_slowdown(rand.pools):.3f}x "
          f"aware={placed_slowdown(aware.pools):.3f}x")

    sensitive = max(jobs, key=lambda j: 1 - j.sensitivity(0.5))
    base = simulate_colocation(sensitive, 100, loi_range=(0, 0.5), seed=1)
    opt = simulate_colocation(sensitive, 100, loi_range=(0, 0.2), seed=1)
    sb, so = five_number_summary(base), five_number_summary(opt)
    print(f"\nFig13 for most-sensitive job ({sensitive.name}):")
    print(f"  random: median={sb['median']:.3e}s p75={sb['p75']:.3e}s")
    print(f"  aware : median={so['median']:.3e}s p75={so['p75']:.3e}s "
          f"({100 * (sb['p75'] - so['p75']) / sb['p75']:.1f}% p75 cut)")


if __name__ == "__main__":
    main()
