"""Quickstart: the paper's three-level quantitative analysis on one arch.

    PYTHONPATH=src:. python examples/quickstart.py [arch] [shape]

Level 1 characterizes the workload's intrinsic memory behaviour, Level 2
places its state across HBM/host-pool tiers and checks the R_cap <=
R_access <= R_bw corridor, Level 3 predicts interference sensitivity and
the interference coefficient a scheduler would use.
"""

import sys

sys.path.insert(0, "src")

from repro.core.quantify import analyze  # noqa: E402


def main():
    arch = sys.argv[1] if len(sys.argv) > 1 else "kimi-k2-1t-a32b"
    shape = sys.argv[2] if len(sys.argv) > 2 else "decode_32k"

    print(f"=== {arch} x {shape} on 256x v5e + host pool ===\n")
    for policy in ("first_touch", "hotness", "balanced_bw"):
        a = analyze(arch, shape, policy=policy, pool_fraction=0.5)
        l1, l2, l3 = a.level1, a.level2, a.level3
        print(f"--- policy: {policy} ---")
        print(f"  L1 footprint/chip : {l1['footprint_bytes_per_chip'] / 2**30:8.2f} GiB")
        print(f"  L1 traffic/step   : {l1['traffic_bytes_per_step_per_chip'] / 2**30:8.2f} GiB")
        print(f"  L1 arithmetic int.: {l1['arithmetic_intensity']:8.1f} flop/B")
        print(f"  L1 hot-50% curve  : {l1['hot50'] * 100:8.1f} % of traffic")
        print(f"  L2 R_cap  (pool)  : {l2['r_cap_pool']:8.3f}")
        print(f"  L2 R_access(pool) : {l2['r_access_pool']:8.3f}")
        print(f"  L2 R_bw   (pool)  : {l2['r_bw_pool']:8.3f}")
        print(f"  L2 in corridor    : {l2['in_corridor']}")
        print(f"  L2 mem slowdown   : {l2['slowdown_vs_all_hbm']:8.2f}x vs all-HBM")
        print(f"  L3 sens @ LoI=50% : {l3['sensitivity']['loi_50']:8.3f}")
        print(f"  L3 IC             : {l3['interference_coefficient']:8.3f}")
        print()
    print("reading: hotness should cut R_access vs first_touch; if "
          "R_access >> R_bw the job is pool-link-bound and (per the paper) "
          "should scale out instead of pooling deeper.")


if __name__ == "__main__":
    main()
